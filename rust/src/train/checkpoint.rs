//! Checkpointing: self-describing binary formats for model parameters and
//! full training state.
//!
//! Two formats share one hardened tensor-table codec (bounded lengths,
//! truncation-aware reads, no unsafe byte reinterpretation):
//!
//! `RTPC1` — bare parameters (little-endian):
//!   magic "RTPC1\0" | u32 tensor count
//!   per tensor: u32 name_len | name bytes | u32 ndim | u64 dims... |
//!               f32 data...
//!
//! `RTPC2` — elastic training state. Everything is stored at FULL
//! (world-size-independent) shape: params plus each optimizer moment as a
//! complete `ModelParams`-shaped tensor table, so a run killed at world
//! size N resumes at any N' via each engine's `load_full` re-sharding.
//!   magic "RTPC2\0" | u32 world_size | u64 step | u32 rotation_offset |
//!   u8 opt_kind | u64 opt_step | f32 lr |
//!   u64 corpus_seed | 4 x u64 corpus_rng | u64 corpus_state |
//!   u32 moment_count | params table | moment tables...

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::{ModelCfg, OptimizerKind};
use crate::model::ModelParams;
use crate::parallel::Engine;
use crate::tensor::HostTensor;

use super::corpus::{CorpusState, MarkovCorpus};
use super::optimizer::Optimizer;

const MAGIC_V1: &[u8; 6] = b"RTPC1\0";
const MAGIC_V2: &[u8; 6] = b"RTPC2\0";

/// Sanity bounds on deserialized lengths: a corrupt or truncated header
/// must produce a readable error, never a multi-gigabyte allocation.
const MAX_NAME_LEN: usize = 4096;
const MAX_NDIM: usize = 8;
const MAX_NUMEL: usize = 1 << 28;
const MAX_TENSORS: usize = 1 << 20;
const MAX_MOMENTS: usize = 8;

// ---------------------------------------------------------------------
// primitive reads/writes (safe, little-endian, truncation-aware)
// ---------------------------------------------------------------------

fn read_u32(f: &mut impl Read, what: &str) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b).with_context(|| format!("truncated checkpoint: reading {what}"))?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read, what: &str) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b).with_context(|| format!("truncated checkpoint: reading {what}"))?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32(f: &mut impl Read, what: &str) -> Result<f32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b).with_context(|| format!("truncated checkpoint: reading {what}"))?;
    Ok(f32::from_le_bytes(b))
}

fn write_f32s(f: &mut impl Write, data: &[f32]) -> Result<()> {
    // chunked to keep the staging buffer small on big tensors
    let mut buf = Vec::with_capacity(4 * data.len().min(1 << 16));
    for chunk in data.chunks(1 << 16) {
        buf.clear();
        for x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&buf)?;
    }
    Ok(())
}

fn read_f32s(f: &mut impl Read, n: usize, what: &str) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(n);
    let mut buf = vec![0u8; 4 * n.min(1 << 16)];
    let mut left = n;
    while left > 0 {
        let take = left.min(1 << 16);
        let bytes = &mut buf[..4 * take];
        f.read_exact(bytes)
            .with_context(|| format!("truncated checkpoint: reading {what}"))?;
        out.extend(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])));
        left -= take;
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// crash-atomic file writes (shared by RTPC1 and RTPC2)
// ---------------------------------------------------------------------

/// The staging sibling a crash-atomic write streams into before the
/// rename: `<path>.tmp`.
pub fn tmp_sibling(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// Crash-atomic write: stream into `<path>.tmp`, flush + fsync, then
/// rename over `path` (and best-effort fsync the parent directory so the
/// rename itself is durable). A writer killed at ANY point leaves either
/// the previous complete file or the new complete file at `path` — never
/// a torn one. The readers' corruption/truncation bails stay as the
/// second line of defense.
fn write_atomic(
    path: &Path,
    write: impl FnOnce(&mut std::io::BufWriter<std::fs::File>) -> Result<()>,
) -> Result<()> {
    let tmp = tmp_sibling(path);
    let file = std::fs::File::create(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    let mut f = std::io::BufWriter::new(file);
    let streamed = write(&mut f).and_then(|()| {
        f.flush()?;
        f.get_ref().sync_all()?;
        Ok(())
    });
    if let Err(e) = streamed {
        drop(f);
        std::fs::remove_file(&tmp).ok();
        return Err(e).with_context(|| format!("writing {}", tmp.display()));
    }
    drop(f);
    std::fs::rename(&tmp, path).with_context(|| {
        format!("renaming {} -> {}", tmp.display(), path.display())
    })?;
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        if let Ok(d) = std::fs::File::open(dir) {
            d.sync_all().ok();
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// tensor-table codec (shared by RTPC1 and RTPC2)
// ---------------------------------------------------------------------

fn write_tensor_table(f: &mut impl Write, params: &ModelParams) -> Result<()> {
    let mut entries: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
    params.visit(&mut |name, t| {
        entries.push((name.to_string(), t.shape.clone(), t.data.clone()));
    });
    f.write_all(&(entries.len() as u32).to_le_bytes())?;
    for (name, shape, data) in entries {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(shape.len() as u32).to_le_bytes())?;
        for d in &shape {
            f.write_all(&(*d as u64).to_le_bytes())?;
        }
        write_f32s(f, &data)?;
    }
    Ok(())
}

/// Read one tensor table and pour it into a cfg-shaped `ModelParams`,
/// validating coverage and shapes. `label` names the table in errors
/// ("params", "moment 1", ...).
fn read_tensor_table(f: &mut impl Read, cfg: &ModelCfg, label: &str) -> Result<ModelParams> {
    let count = read_u32(f, "tensor count")? as usize;
    if count > MAX_TENSORS {
        bail!("corrupt checkpoint: {label} claims {count} tensors");
    }
    let mut tensors: std::collections::BTreeMap<String, HostTensor> = Default::default();
    for i in 0..count {
        let name_len = read_u32(f, "tensor name length")? as usize;
        if name_len > MAX_NAME_LEN {
            bail!("corrupt checkpoint: {label} tensor {i} name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)
            .with_context(|| format!("truncated checkpoint: {label} tensor {i} name"))?;
        let name = String::from_utf8(name).context("tensor name not utf8")?;
        let ndim = read_u32(f, "tensor rank")? as usize;
        if ndim > MAX_NDIM {
            bail!("corrupt checkpoint: tensor {name:?} claims rank {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        let mut numel = 1usize;
        for _ in 0..ndim {
            let d = read_u64(f, "tensor dim")? as usize;
            numel = numel.saturating_mul(d);
            shape.push(d);
        }
        if numel > MAX_NUMEL {
            bail!("corrupt checkpoint: tensor {name:?} claims shape {shape:?}");
        }
        let data = read_f32s(f, numel, "tensor data")?;
        tensors.insert(name, HostTensor::from_vec(&shape, data));
    }
    let mut out = ModelParams::zeros_like(cfg);
    let mut missing = Vec::new();
    out.visit_mut(&mut |name, t| match tensors.remove(name) {
        Some(loaded) if loaded.shape == t.shape => *t = loaded,
        Some(loaded) => missing.push(format!(
            "{name}: shape {:?} != expected {:?}",
            loaded.shape, t.shape
        )),
        None => missing.push(format!("{name}: absent")),
    });
    if !missing.is_empty() {
        bail!("checkpoint {label} does not match config: {}", missing.join("; "));
    }
    if !tensors.is_empty() {
        bail!(
            "checkpoint {label} has {} extra tensors (e.g. {:?})",
            tensors.len(),
            tensors.keys().next()
        );
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// RTPC1: bare parameters
// ---------------------------------------------------------------------

pub fn save_params(params: &ModelParams, path: &Path) -> Result<()> {
    write_atomic(path, |f| {
        f.write_all(MAGIC_V1)?;
        write_tensor_table(f, params)
    })
}

pub fn load_params(cfg: &ModelCfg, path: &Path) -> Result<ModelParams> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)
        .with_context(|| format!("{}: truncated checkpoint header", path.display()))?;
    if &magic != MAGIC_V1 {
        bail!("{}: not an RTP checkpoint", path.display());
    }
    read_tensor_table(&mut f, cfg, "params")
        .with_context(|| format!("loading {}", path.display()))
}

// ---------------------------------------------------------------------
// RTPC2: elastic training state
// ---------------------------------------------------------------------

/// Full training state at FULL (world-size-independent) shape. A
/// checkpoint taken at any world size resumes at any other: params and
/// per-moment optimizer state re-shard through `Engine::load_full`,
/// and the corpus cursor + optimizer step counter make the continuation
/// bit-identical to an uninterrupted run at the new world size.
pub struct TrainState {
    /// World size of the run that SAVED the state (informational — the
    /// state itself is world-size independent).
    pub world_size: usize,
    /// Training steps completed before the save.
    pub step: u64,
    /// RTP ring-rotation offset at the save point. Engines always finish
    /// a step with rings rotated home, so this is 0 at every step
    /// boundary; it rides the format so a mid-step save is detectable.
    pub rotation_offset: u32,
    pub opt_kind: OptimizerKind,
    pub opt_step: u64,
    pub lr: f32,
    pub corpus: CorpusState,
    pub params: ModelParams,
    /// One FULL `ModelParams`-shaped table per optimizer moment
    /// (momentum: 1; Adam: m then v).
    pub moments: Vec<ModelParams>,
}

fn kind_byte(k: OptimizerKind) -> u8 {
    match k {
        OptimizerKind::Sgd => 0,
        OptimizerKind::Momentum => 1,
        OptimizerKind::Adam => 2,
    }
}

fn kind_from_byte(b: u8) -> Result<OptimizerKind> {
    Ok(match b {
        0 => OptimizerKind::Sgd,
        1 => OptimizerKind::Momentum,
        2 => OptimizerKind::Adam,
        _ => bail!("corrupt checkpoint: unknown optimizer kind {b}"),
    })
}

pub fn save_train_state(state: &TrainState, path: &Path) -> Result<()> {
    write_atomic(path, |f| {
        f.write_all(MAGIC_V2)?;
        f.write_all(&(state.world_size as u32).to_le_bytes())?;
        f.write_all(&state.step.to_le_bytes())?;
        f.write_all(&state.rotation_offset.to_le_bytes())?;
        f.write_all(&[kind_byte(state.opt_kind)])?;
        f.write_all(&state.opt_step.to_le_bytes())?;
        f.write_all(&state.lr.to_le_bytes())?;
        f.write_all(&state.corpus.seed.to_le_bytes())?;
        for s in state.corpus.rng {
            f.write_all(&s.to_le_bytes())?;
        }
        f.write_all(&state.corpus.state.to_le_bytes())?;
        f.write_all(&(state.moments.len() as u32).to_le_bytes())?;
        write_tensor_table(f, &state.params)?;
        for m in &state.moments {
            write_tensor_table(f, m)?;
        }
        Ok(())
    })
}

pub fn load_train_state(cfg: &ModelCfg, path: &Path) -> Result<TrainState> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)
        .with_context(|| format!("{}: truncated checkpoint header", path.display()))?;
    if &magic != MAGIC_V2 {
        bail!("{}: not an RTPC2 training checkpoint", path.display());
    }
    let inner = (|| -> Result<TrainState> {
        let world_size = read_u32(&mut f, "world size")? as usize;
        let step = read_u64(&mut f, "step")?;
        let rotation_offset = read_u32(&mut f, "rotation offset")?;
        if rotation_offset != 0 {
            bail!(
                "checkpoint taken mid-step (rotation offset {rotation_offset}); \
                 only step-boundary checkpoints are resumable"
            );
        }
        let mut kb = [0u8; 1];
        f.read_exact(&mut kb).context("truncated checkpoint: reading optimizer kind")?;
        let opt_kind = kind_from_byte(kb[0])?;
        let opt_step = read_u64(&mut f, "optimizer step")?;
        let lr = read_f32(&mut f, "lr")?;
        let corpus = CorpusState {
            seed: read_u64(&mut f, "corpus seed")?,
            rng: [
                read_u64(&mut f, "corpus rng")?,
                read_u64(&mut f, "corpus rng")?,
                read_u64(&mut f, "corpus rng")?,
                read_u64(&mut f, "corpus rng")?,
            ],
            state: read_u64(&mut f, "corpus state")?,
        };
        let n_moments = read_u32(&mut f, "moment count")? as usize;
        if n_moments > MAX_MOMENTS {
            bail!("corrupt checkpoint: claims {n_moments} optimizer moments");
        }
        if n_moments != opt_kind.state_factor() {
            bail!(
                "corrupt checkpoint: {opt_kind:?} optimizer with {n_moments} moments"
            );
        }
        let params = read_tensor_table(&mut f, cfg, "params")?;
        let mut moments = Vec::with_capacity(n_moments);
        for k in 0..n_moments {
            moments.push(read_tensor_table(&mut f, cfg, &format!("moment {k}"))?);
        }
        Ok(TrainState {
            world_size,
            step,
            rotation_offset,
            opt_kind,
            opt_step,
            lr,
            corpus,
            params,
            moments,
        })
    })();
    inner.with_context(|| format!("loading {}", path.display()))
}

/// Assemble the full training state from a live engine + optimizer +
/// corpus. Uses the engine's own `gather_params` to reassemble each
/// optimizer moment (staged into the param tensors, then restored), so
/// the result is identical from every engine and world size.
pub fn capture_train_state(
    engine: &mut dyn Engine,
    opt: &Optimizer,
    corpus: &MarkovCorpus,
    step: u64,
) -> Result<TrainState> {
    let params = engine.gather_params();
    let mut moments = Vec::with_capacity(opt.moment_count());
    for k in 0..opt.moment_count() {
        opt.stage_moment_into_params(&mut *engine, k);
        moments.push(engine.gather_params());
    }
    if !moments.is_empty() {
        // staging overwrote the live weights; put them back
        engine.load_full(&params)?;
    }
    Ok(TrainState {
        world_size: engine.ctx().cluster.n(),
        step,
        rotation_offset: 0,
        opt_kind: opt.kind,
        opt_step: opt.step_count(),
        lr: opt.lr,
        corpus: corpus.snapshot(),
        params,
        moments,
    })
}

/// Hydrate an engine + fresh optimizer from a [`TrainState`] — possibly
/// at a different world size than the save — and rebuild the corpus
/// cursor. Returns the restored corpus.
pub fn restore_train_state(
    engine: &mut dyn Engine,
    opt: &mut Optimizer,
    cfg: &ModelCfg,
    state: &TrainState,
) -> Result<MarkovCorpus> {
    if opt.kind != state.opt_kind {
        bail!(
            "optimizer kind mismatch: checkpoint has {:?}, engine run uses {:?}",
            state.opt_kind,
            opt.kind
        );
    }
    for (k, moment) in state.moments.iter().enumerate() {
        engine.load_full(moment)?;
        opt.load_moment_from_params(&mut *engine, k);
    }
    opt.set_step_count(state.opt_step);
    opt.lr = state.lr;
    engine.load_full(&state.params)?;
    Ok(MarkovCorpus::restore(cfg, state.corpus))
}

// ---------------------------------------------------------------------
// async off-thread checkpointing
// ---------------------------------------------------------------------

/// Counters from an [`AsyncCheckpointer`] run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CkptStats {
    /// Snapshots offered to the writer (`submit` calls).
    pub submitted: u64,
    /// Snapshots fully written (fsynced + renamed) to disk.
    pub written: u64,
    /// Snapshots dropped because the double buffer was full — the writer
    /// was still flushing the previous one. Dropping (instead of
    /// blocking) is the contract: the step path never waits on disk.
    pub skipped: u64,
    /// Total nanoseconds the submitting thread spent inside `submit`
    /// (the channel hand-off only — gated as `ckpt_async_stall_ns`).
    pub submit_stall_ns: u64,
}

/// Periodic checkpointing off the training thread: a dedicated writer
/// thread drains a bounded(1) channel of [`TrainState`] snapshots and
/// streams each through the crash-atomic [`save_train_state`] path. The
/// bounded channel is the double buffer — at most one snapshot queued
/// while one is being written; `submit` uses `try_send` and NEVER blocks
/// the step path (a full buffer drops the snapshot and counts it in
/// [`CkptStats::skipped`]).
pub struct AsyncCheckpointer {
    tx: Option<SyncSender<Arc<TrainState>>>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
    written: Arc<AtomicU64>,
    stats: CkptStats,
    path: PathBuf,
}

impl AsyncCheckpointer {
    pub fn new(path: &Path) -> AsyncCheckpointer {
        let (tx, rx) = sync_channel::<Arc<TrainState>>(1);
        let written = Arc::new(AtomicU64::new(0));
        let w = Arc::clone(&written);
        let p = path.to_path_buf();
        let handle = std::thread::Builder::new()
            .name("rtp-ckpt-writer".to_string())
            .spawn(move || -> Result<()> {
                while let Ok(state) = rx.recv() {
                    save_train_state(&state, &p)?;
                    w.fetch_add(1, Ordering::Release);
                }
                Ok(())
            })
            .expect("spawning checkpoint writer thread");
        AsyncCheckpointer {
            tx: Some(tx),
            handle: Some(handle),
            written,
            stats: CkptStats::default(),
            path: path.to_path_buf(),
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Hand a snapshot to the writer. Non-blocking: a busy writer means
    /// the snapshot is dropped (counted as skipped), a dead writer means
    /// the same (its error surfaces from [`finish`](Self::finish)).
    pub fn submit(&mut self, state: Arc<TrainState>) {
        let t0 = Instant::now();
        self.stats.submitted += 1;
        match self.tx.as_ref().expect("checkpointer already finished").try_send(state) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                self.stats.skipped += 1;
            }
        }
        self.stats.submit_stall_ns += t0.elapsed().as_nanos() as u64;
    }

    /// Like [`submit`](Self::submit), but waits for buffer space instead
    /// of dropping. End-of-run use only: the LAST snapshot of a run must
    /// reach disk (it is the state a `--resume` continues from), so the
    /// caller trades one bounded wait for durability. The step path never
    /// calls this.
    pub fn submit_final(&mut self, state: Arc<TrainState>) {
        self.stats.submitted += 1;
        // a dead writer is not a drop: its error surfaces from finish().
        // Deliberately NOT counted in submit_stall_ns — that counter gates
        // the STEP path's stall, and this wait happens after the last step.
        let _ = self.tx.as_ref().expect("checkpointer already finished").send(state);
    }

    /// Stats so far; `written` reflects completed (renamed) saves only.
    pub fn stats(&self) -> CkptStats {
        CkptStats { written: self.written.load(Ordering::Acquire), ..self.stats }
    }

    /// Drain the queue, join the writer, and surface any write error.
    pub fn finish(mut self) -> Result<CkptStats> {
        drop(self.tx.take());
        let joined = self
            .handle
            .take()
            .expect("checkpointer already finished")
            .join()
            .map_err(|_| anyhow::anyhow!("checkpoint writer thread panicked"))?;
        joined.with_context(|| format!("async checkpoint write to {}", self.path.display()))?;
        Ok(self.stats())
    }
}

impl Drop for AsyncCheckpointer {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Strategy};
    use crate::parallel::{build_engine, EngineOpts, ExecKind};
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("rtp-ckpt-{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip_exact() {
        let cfg = presets::get("tiny").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(3));
        let path = tmp("roundtrip");
        save_params(&p, &path).unwrap();
        let q = load_params(&cfg, &path).unwrap();
        assert_eq!(p.max_abs_diff(&q), 0.0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn moe_roundtrip() {
        let cfg = presets::get("tiny-moe").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(4));
        let path = tmp("moe");
        save_params(&p, &path).unwrap();
        let q = load_params(&cfg, &path).unwrap();
        assert_eq!(p.max_abs_diff(&q), 0.0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn wrong_config_rejected() {
        let cfg = presets::get("tiny").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(5));
        let path = tmp("wrongcfg");
        save_params(&p, &path).unwrap();
        let other = presets::get("tiny-moe").unwrap();
        assert!(load_params(&other, &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn garbage_rejected() {
        let path = tmp("garbage");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let cfg = presets::get("tiny").unwrap();
        assert!(load_params(&cfg, &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncated_file_rejected_with_context() {
        let cfg = presets::get("tiny").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(6));
        let path = tmp("truncated");
        save_params(&p, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        for frac in [full.len() / 2, full.len() - 3, 7] {
            std::fs::write(&path, &full[..frac]).unwrap();
            let err = load_params(&cfg, &path).unwrap_err();
            assert!(
                format!("{err:#}").contains("truncated"),
                "frac {frac}: error lacks truncation context: {err:#}"
            );
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn absurd_lengths_rejected_not_allocated() {
        let cfg = presets::get("tiny").unwrap();
        // valid magic, then a name length claiming 4 GB — must error,
        // not attempt the allocation
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // one tensor
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // insane name_len
        let path = tmp("absurd");
        std::fs::write(&path, &bytes).unwrap();
        let err = load_params(&cfg, &path).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn absurd_tensor_shape_rejected() {
        let cfg = presets::get("tiny").unwrap();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&3u32.to_le_bytes()); // name_len 3
        bytes.extend_from_slice(b"wte");
        bytes.extend_from_slice(&2u32.to_le_bytes()); // ndim 2
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes());
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes());
        let path = tmp("absurd-shape");
        std::fs::write(&path, &bytes).unwrap();
        let err = load_params(&cfg, &path).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v1_magic_rejected_as_train_state() {
        let cfg = presets::get("tiny").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(8));
        let path = tmp("v1-as-v2");
        save_params(&p, &path).unwrap();
        assert!(load_train_state(&cfg, &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn train_state_roundtrip_bitwise() {
        let cfg = presets::get("tiny").unwrap();
        let mut eng =
            build_engine(&EngineOpts::new("tiny", Strategy::Ddp, 2, 4).exec(ExecKind::Oracle))
                .unwrap();
        let mut opt = Optimizer::new(OptimizerKind::Adam, 1e-2);
        let mut corpus = MarkovCorpus::new(&cfg, 11);
        for _ in 0..3 {
            let b = corpus.next_batch(4);
            eng.zero_grads();
            eng.step(&b).unwrap();
            opt.step(&mut *eng);
        }
        let before = eng.gather_params();
        let state = capture_train_state(&mut *eng, &opt, &corpus, 3).unwrap();
        // capture must leave the live weights untouched
        assert_eq!(before.max_abs_diff(&eng.gather_params()), 0.0);
        let path = tmp("trainstate");
        save_train_state(&state, &path).unwrap();
        let loaded = load_train_state(&cfg, &path).unwrap();
        assert_eq!(loaded.world_size, 2);
        assert_eq!(loaded.step, 3);
        assert_eq!(loaded.opt_kind, OptimizerKind::Adam);
        assert_eq!(loaded.opt_step, 3);
        assert_eq!(loaded.lr, 1e-2);
        assert_eq!(loaded.corpus, corpus.snapshot());
        assert_eq!(loaded.params.max_abs_diff(&state.params), 0.0);
        assert_eq!(loaded.moments.len(), 2);
        for (a, b) in loaded.moments.iter().zip(&state.moments) {
            assert_eq!(a.max_abs_diff(b), 0.0);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn atomic_save_leaves_no_tmp_and_stale_tmp_is_harmless() {
        let cfg = presets::get("tiny").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(9));
        let path = tmp("atomic");
        save_params(&p, &path).unwrap();
        assert!(!tmp_sibling(&path).exists(), "atomic save must clean up its .tmp");
        // a torn .tmp left by a writer killed mid-save must not affect
        // loading the real path, and the next save must still land
        std::fs::write(tmp_sibling(&path), b"torn partial write").unwrap();
        let q = load_params(&cfg, &path).unwrap();
        assert_eq!(p.max_abs_diff(&q), 0.0);
        save_params(&p, &path).unwrap();
        assert!(!tmp_sibling(&path).exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn atomic_save_never_truncates_destination_before_rename() {
        // simulate a writer killed BEFORE the rename: the destination
        // must still hold the previous complete checkpoint
        let cfg = presets::get("tiny").unwrap();
        let old = ModelParams::init(&cfg, &mut Rng::new(10));
        let path = tmp("atomic-prev");
        save_params(&old, &path).unwrap();
        std::fs::write(tmp_sibling(&path), b"RTPC1\0 half-written garbage").unwrap();
        let q = load_params(&cfg, &path).unwrap();
        assert_eq!(old.max_abs_diff(&q), 0.0);
        std::fs::remove_file(tmp_sibling(&path)).ok();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn async_checkpointer_writes_loadable_state_and_counts_drops() {
        let cfg = presets::get("tiny").unwrap();
        let mut eng =
            build_engine(&EngineOpts::new("tiny", Strategy::Ddp, 2, 4).exec(ExecKind::Oracle))
                .unwrap();
        let mut opt = Optimizer::new(OptimizerKind::Adam, 1e-2);
        let mut corpus = MarkovCorpus::new(&cfg, 13);
        let path = tmp("async");
        let mut ckpt = AsyncCheckpointer::new(&path);
        for s in 1..=4u64 {
            let b = corpus.next_batch(4);
            eng.zero_grads();
            eng.step(&b).unwrap();
            opt.step(&mut *eng);
            let state = capture_train_state(&mut *eng, &opt, &corpus, s).unwrap();
            ckpt.submit(Arc::new(state));
        }
        let stats = ckpt.finish().unwrap();
        assert_eq!(stats.submitted, 4);
        assert_eq!(stats.written + stats.skipped, 4);
        assert!(stats.written >= 1, "{stats:?}");
        let loaded = load_train_state(&cfg, &path).unwrap();
        assert!(loaded.step >= 1 && loaded.step <= 4, "{}", loaded.step);
        assert!(!tmp_sibling(&path).exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_train_state_rejected() {
        let cfg = presets::get("tiny").unwrap();
        let mut eng =
            build_engine(&EngineOpts::new("tiny", Strategy::Single, 1, 4).exec(ExecKind::Oracle))
                .unwrap();
        let opt = Optimizer::new(OptimizerKind::Sgd, 1e-2);
        let corpus = MarkovCorpus::new(&cfg, 12);
        let state = capture_train_state(&mut *eng, &opt, &corpus, 0).unwrap();
        let path = tmp("trainstate-trunc");
        save_train_state(&state, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 3]).unwrap();
        let err = load_train_state(&cfg, &path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        std::fs::remove_file(path).ok();
    }
}
