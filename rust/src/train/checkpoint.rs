//! Checkpointing: self-describing binary formats for model parameters and
//! full training state.
//!
//! Two formats share one hardened tensor-table codec (bounded lengths,
//! truncation-aware reads, no unsafe byte reinterpretation):
//!
//! `RTPC1` — bare parameters (little-endian):
//!   magic "RTPC1\0" | u32 tensor count
//!   per tensor: u32 name_len | name bytes | u32 ndim | u64 dims... |
//!               f32 data...
//!
//! `RTPC2` — elastic training state. Everything is stored at FULL
//! (world-size-independent) shape: params plus each optimizer moment as a
//! complete `ModelParams`-shaped tensor table, so a run killed at world
//! size N resumes at any N' via each engine's `load_full` re-sharding.
//!   magic "RTPC2\0" | u32 world_size | u64 step | u32 rotation_offset |
//!   u8 opt_kind | u64 opt_step | f32 lr |
//!   u64 corpus_seed | 4 x u64 corpus_rng | u64 corpus_state |
//!   u32 moment_count | params table | moment tables...

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{ModelCfg, OptimizerKind};
use crate::model::ModelParams;
use crate::parallel::Engine;
use crate::tensor::HostTensor;

use super::corpus::{CorpusState, MarkovCorpus};
use super::optimizer::Optimizer;

const MAGIC_V1: &[u8; 6] = b"RTPC1\0";
const MAGIC_V2: &[u8; 6] = b"RTPC2\0";

/// Sanity bounds on deserialized lengths: a corrupt or truncated header
/// must produce a readable error, never a multi-gigabyte allocation.
const MAX_NAME_LEN: usize = 4096;
const MAX_NDIM: usize = 8;
const MAX_NUMEL: usize = 1 << 28;
const MAX_TENSORS: usize = 1 << 20;
const MAX_MOMENTS: usize = 8;

// ---------------------------------------------------------------------
// primitive reads/writes (safe, little-endian, truncation-aware)
// ---------------------------------------------------------------------

fn read_u32(f: &mut impl Read, what: &str) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b).with_context(|| format!("truncated checkpoint: reading {what}"))?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read, what: &str) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b).with_context(|| format!("truncated checkpoint: reading {what}"))?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32(f: &mut impl Read, what: &str) -> Result<f32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b).with_context(|| format!("truncated checkpoint: reading {what}"))?;
    Ok(f32::from_le_bytes(b))
}

fn write_f32s(f: &mut impl Write, data: &[f32]) -> Result<()> {
    // chunked to keep the staging buffer small on big tensors
    let mut buf = Vec::with_capacity(4 * data.len().min(1 << 16));
    for chunk in data.chunks(1 << 16) {
        buf.clear();
        for x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&buf)?;
    }
    Ok(())
}

fn read_f32s(f: &mut impl Read, n: usize, what: &str) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(n);
    let mut buf = vec![0u8; 4 * n.min(1 << 16)];
    let mut left = n;
    while left > 0 {
        let take = left.min(1 << 16);
        let bytes = &mut buf[..4 * take];
        f.read_exact(bytes)
            .with_context(|| format!("truncated checkpoint: reading {what}"))?;
        out.extend(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])));
        left -= take;
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// tensor-table codec (shared by RTPC1 and RTPC2)
// ---------------------------------------------------------------------

fn write_tensor_table(f: &mut impl Write, params: &ModelParams) -> Result<()> {
    let mut entries: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
    params.visit(&mut |name, t| {
        entries.push((name.to_string(), t.shape.clone(), t.data.clone()));
    });
    f.write_all(&(entries.len() as u32).to_le_bytes())?;
    for (name, shape, data) in entries {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(shape.len() as u32).to_le_bytes())?;
        for d in &shape {
            f.write_all(&(*d as u64).to_le_bytes())?;
        }
        write_f32s(f, &data)?;
    }
    Ok(())
}

/// Read one tensor table and pour it into a cfg-shaped `ModelParams`,
/// validating coverage and shapes. `label` names the table in errors
/// ("params", "moment 1", ...).
fn read_tensor_table(f: &mut impl Read, cfg: &ModelCfg, label: &str) -> Result<ModelParams> {
    let count = read_u32(f, "tensor count")? as usize;
    if count > MAX_TENSORS {
        bail!("corrupt checkpoint: {label} claims {count} tensors");
    }
    let mut tensors: std::collections::BTreeMap<String, HostTensor> = Default::default();
    for i in 0..count {
        let name_len = read_u32(f, "tensor name length")? as usize;
        if name_len > MAX_NAME_LEN {
            bail!("corrupt checkpoint: {label} tensor {i} name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)
            .with_context(|| format!("truncated checkpoint: {label} tensor {i} name"))?;
        let name = String::from_utf8(name).context("tensor name not utf8")?;
        let ndim = read_u32(f, "tensor rank")? as usize;
        if ndim > MAX_NDIM {
            bail!("corrupt checkpoint: tensor {name:?} claims rank {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        let mut numel = 1usize;
        for _ in 0..ndim {
            let d = read_u64(f, "tensor dim")? as usize;
            numel = numel.saturating_mul(d);
            shape.push(d);
        }
        if numel > MAX_NUMEL {
            bail!("corrupt checkpoint: tensor {name:?} claims shape {shape:?}");
        }
        let data = read_f32s(f, numel, "tensor data")?;
        tensors.insert(name, HostTensor::from_vec(&shape, data));
    }
    let mut out = ModelParams::zeros_like(cfg);
    let mut missing = Vec::new();
    out.visit_mut(&mut |name, t| match tensors.remove(name) {
        Some(loaded) if loaded.shape == t.shape => *t = loaded,
        Some(loaded) => missing.push(format!(
            "{name}: shape {:?} != expected {:?}",
            loaded.shape, t.shape
        )),
        None => missing.push(format!("{name}: absent")),
    });
    if !missing.is_empty() {
        bail!("checkpoint {label} does not match config: {}", missing.join("; "));
    }
    if !tensors.is_empty() {
        bail!(
            "checkpoint {label} has {} extra tensors (e.g. {:?})",
            tensors.len(),
            tensors.keys().next()
        );
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// RTPC1: bare parameters
// ---------------------------------------------------------------------

pub fn save_params(params: &ModelParams, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC_V1)?;
    write_tensor_table(&mut f, params)?;
    Ok(())
}

pub fn load_params(cfg: &ModelCfg, path: &Path) -> Result<ModelParams> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)
        .with_context(|| format!("{}: truncated checkpoint header", path.display()))?;
    if &magic != MAGIC_V1 {
        bail!("{}: not an RTP checkpoint", path.display());
    }
    read_tensor_table(&mut f, cfg, "params")
        .with_context(|| format!("loading {}", path.display()))
}

// ---------------------------------------------------------------------
// RTPC2: elastic training state
// ---------------------------------------------------------------------

/// Full training state at FULL (world-size-independent) shape. A
/// checkpoint taken at any world size resumes at any other: params and
/// per-moment optimizer state re-shard through `Engine::load_full`,
/// and the corpus cursor + optimizer step counter make the continuation
/// bit-identical to an uninterrupted run at the new world size.
pub struct TrainState {
    /// World size of the run that SAVED the state (informational — the
    /// state itself is world-size independent).
    pub world_size: usize,
    /// Training steps completed before the save.
    pub step: u64,
    /// RTP ring-rotation offset at the save point. Engines always finish
    /// a step with rings rotated home, so this is 0 at every step
    /// boundary; it rides the format so a mid-step save is detectable.
    pub rotation_offset: u32,
    pub opt_kind: OptimizerKind,
    pub opt_step: u64,
    pub lr: f32,
    pub corpus: CorpusState,
    pub params: ModelParams,
    /// One FULL `ModelParams`-shaped table per optimizer moment
    /// (momentum: 1; Adam: m then v).
    pub moments: Vec<ModelParams>,
}

fn kind_byte(k: OptimizerKind) -> u8 {
    match k {
        OptimizerKind::Sgd => 0,
        OptimizerKind::Momentum => 1,
        OptimizerKind::Adam => 2,
    }
}

fn kind_from_byte(b: u8) -> Result<OptimizerKind> {
    Ok(match b {
        0 => OptimizerKind::Sgd,
        1 => OptimizerKind::Momentum,
        2 => OptimizerKind::Adam,
        _ => bail!("corrupt checkpoint: unknown optimizer kind {b}"),
    })
}

pub fn save_train_state(state: &TrainState, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC_V2)?;
    f.write_all(&(state.world_size as u32).to_le_bytes())?;
    f.write_all(&state.step.to_le_bytes())?;
    f.write_all(&state.rotation_offset.to_le_bytes())?;
    f.write_all(&[kind_byte(state.opt_kind)])?;
    f.write_all(&state.opt_step.to_le_bytes())?;
    f.write_all(&state.lr.to_le_bytes())?;
    f.write_all(&state.corpus.seed.to_le_bytes())?;
    for s in state.corpus.rng {
        f.write_all(&s.to_le_bytes())?;
    }
    f.write_all(&state.corpus.state.to_le_bytes())?;
    f.write_all(&(state.moments.len() as u32).to_le_bytes())?;
    write_tensor_table(&mut f, &state.params)?;
    for m in &state.moments {
        write_tensor_table(&mut f, m)?;
    }
    Ok(())
}

pub fn load_train_state(cfg: &ModelCfg, path: &Path) -> Result<TrainState> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)
        .with_context(|| format!("{}: truncated checkpoint header", path.display()))?;
    if &magic != MAGIC_V2 {
        bail!("{}: not an RTPC2 training checkpoint", path.display());
    }
    let inner = (|| -> Result<TrainState> {
        let world_size = read_u32(&mut f, "world size")? as usize;
        let step = read_u64(&mut f, "step")?;
        let rotation_offset = read_u32(&mut f, "rotation offset")?;
        if rotation_offset != 0 {
            bail!(
                "checkpoint taken mid-step (rotation offset {rotation_offset}); \
                 only step-boundary checkpoints are resumable"
            );
        }
        let mut kb = [0u8; 1];
        f.read_exact(&mut kb).context("truncated checkpoint: reading optimizer kind")?;
        let opt_kind = kind_from_byte(kb[0])?;
        let opt_step = read_u64(&mut f, "optimizer step")?;
        let lr = read_f32(&mut f, "lr")?;
        let corpus = CorpusState {
            seed: read_u64(&mut f, "corpus seed")?,
            rng: [
                read_u64(&mut f, "corpus rng")?,
                read_u64(&mut f, "corpus rng")?,
                read_u64(&mut f, "corpus rng")?,
                read_u64(&mut f, "corpus rng")?,
            ],
            state: read_u64(&mut f, "corpus state")?,
        };
        let n_moments = read_u32(&mut f, "moment count")? as usize;
        if n_moments > MAX_MOMENTS {
            bail!("corrupt checkpoint: claims {n_moments} optimizer moments");
        }
        if n_moments != opt_kind.state_factor() {
            bail!(
                "corrupt checkpoint: {opt_kind:?} optimizer with {n_moments} moments"
            );
        }
        let params = read_tensor_table(&mut f, cfg, "params")?;
        let mut moments = Vec::with_capacity(n_moments);
        for k in 0..n_moments {
            moments.push(read_tensor_table(&mut f, cfg, &format!("moment {k}"))?);
        }
        Ok(TrainState {
            world_size,
            step,
            rotation_offset,
            opt_kind,
            opt_step,
            lr,
            corpus,
            params,
            moments,
        })
    })();
    inner.with_context(|| format!("loading {}", path.display()))
}

/// Assemble the full training state from a live engine + optimizer +
/// corpus. Uses the engine's own `gather_params` to reassemble each
/// optimizer moment (staged into the param tensors, then restored), so
/// the result is identical from every engine and world size.
pub fn capture_train_state(
    engine: &mut dyn Engine,
    opt: &Optimizer,
    corpus: &MarkovCorpus,
    step: u64,
) -> Result<TrainState> {
    let params = engine.gather_params();
    let mut moments = Vec::with_capacity(opt.moment_count());
    for k in 0..opt.moment_count() {
        opt.stage_moment_into_params(&mut *engine, k);
        moments.push(engine.gather_params());
    }
    if !moments.is_empty() {
        // staging overwrote the live weights; put them back
        engine.load_full(&params)?;
    }
    Ok(TrainState {
        world_size: engine.ctx().cluster.n(),
        step,
        rotation_offset: 0,
        opt_kind: opt.kind,
        opt_step: opt.step_count(),
        lr: opt.lr,
        corpus: corpus.snapshot(),
        params,
        moments,
    })
}

/// Hydrate an engine + fresh optimizer from a [`TrainState`] — possibly
/// at a different world size than the save — and rebuild the corpus
/// cursor. Returns the restored corpus.
pub fn restore_train_state(
    engine: &mut dyn Engine,
    opt: &mut Optimizer,
    cfg: &ModelCfg,
    state: &TrainState,
) -> Result<MarkovCorpus> {
    if opt.kind != state.opt_kind {
        bail!(
            "optimizer kind mismatch: checkpoint has {:?}, engine run uses {:?}",
            state.opt_kind,
            opt.kind
        );
    }
    for (k, moment) in state.moments.iter().enumerate() {
        engine.load_full(moment)?;
        opt.load_moment_from_params(&mut *engine, k);
    }
    opt.set_step_count(state.opt_step);
    opt.lr = state.lr;
    engine.load_full(&state.params)?;
    Ok(MarkovCorpus::restore(cfg, state.corpus))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Strategy};
    use crate::parallel::{build_engine, EngineOpts, ExecKind};
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("rtp-ckpt-{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip_exact() {
        let cfg = presets::get("tiny").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(3));
        let path = tmp("roundtrip");
        save_params(&p, &path).unwrap();
        let q = load_params(&cfg, &path).unwrap();
        assert_eq!(p.max_abs_diff(&q), 0.0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn moe_roundtrip() {
        let cfg = presets::get("tiny-moe").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(4));
        let path = tmp("moe");
        save_params(&p, &path).unwrap();
        let q = load_params(&cfg, &path).unwrap();
        assert_eq!(p.max_abs_diff(&q), 0.0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn wrong_config_rejected() {
        let cfg = presets::get("tiny").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(5));
        let path = tmp("wrongcfg");
        save_params(&p, &path).unwrap();
        let other = presets::get("tiny-moe").unwrap();
        assert!(load_params(&other, &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn garbage_rejected() {
        let path = tmp("garbage");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let cfg = presets::get("tiny").unwrap();
        assert!(load_params(&cfg, &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncated_file_rejected_with_context() {
        let cfg = presets::get("tiny").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(6));
        let path = tmp("truncated");
        save_params(&p, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        for frac in [full.len() / 2, full.len() - 3, 7] {
            std::fs::write(&path, &full[..frac]).unwrap();
            let err = load_params(&cfg, &path).unwrap_err();
            assert!(
                format!("{err:#}").contains("truncated"),
                "frac {frac}: error lacks truncation context: {err:#}"
            );
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn absurd_lengths_rejected_not_allocated() {
        let cfg = presets::get("tiny").unwrap();
        // valid magic, then a name length claiming 4 GB — must error,
        // not attempt the allocation
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // one tensor
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // insane name_len
        let path = tmp("absurd");
        std::fs::write(&path, &bytes).unwrap();
        let err = load_params(&cfg, &path).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn absurd_tensor_shape_rejected() {
        let cfg = presets::get("tiny").unwrap();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&3u32.to_le_bytes()); // name_len 3
        bytes.extend_from_slice(b"wte");
        bytes.extend_from_slice(&2u32.to_le_bytes()); // ndim 2
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes());
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes());
        let path = tmp("absurd-shape");
        std::fs::write(&path, &bytes).unwrap();
        let err = load_params(&cfg, &path).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v1_magic_rejected_as_train_state() {
        let cfg = presets::get("tiny").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(8));
        let path = tmp("v1-as-v2");
        save_params(&p, &path).unwrap();
        assert!(load_train_state(&cfg, &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn train_state_roundtrip_bitwise() {
        let cfg = presets::get("tiny").unwrap();
        let mut eng =
            build_engine(&EngineOpts::new("tiny", Strategy::Ddp, 2, 4).exec(ExecKind::Oracle))
                .unwrap();
        let mut opt = Optimizer::new(OptimizerKind::Adam, 1e-2);
        let mut corpus = MarkovCorpus::new(&cfg, 11);
        for _ in 0..3 {
            let b = corpus.next_batch(4);
            eng.zero_grads();
            eng.step(&b).unwrap();
            opt.step(&mut *eng);
        }
        let before = eng.gather_params();
        let state = capture_train_state(&mut *eng, &opt, &corpus, 3).unwrap();
        // capture must leave the live weights untouched
        assert_eq!(before.max_abs_diff(&eng.gather_params()), 0.0);
        let path = tmp("trainstate");
        save_train_state(&state, &path).unwrap();
        let loaded = load_train_state(&cfg, &path).unwrap();
        assert_eq!(loaded.world_size, 2);
        assert_eq!(loaded.step, 3);
        assert_eq!(loaded.opt_kind, OptimizerKind::Adam);
        assert_eq!(loaded.opt_step, 3);
        assert_eq!(loaded.lr, 1e-2);
        assert_eq!(loaded.corpus, corpus.snapshot());
        assert_eq!(loaded.params.max_abs_diff(&state.params), 0.0);
        assert_eq!(loaded.moments.len(), 2);
        for (a, b) in loaded.moments.iter().zip(&state.moments) {
            assert_eq!(a.max_abs_diff(b), 0.0);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncated_train_state_rejected() {
        let cfg = presets::get("tiny").unwrap();
        let mut eng =
            build_engine(&EngineOpts::new("tiny", Strategy::Single, 1, 4).exec(ExecKind::Oracle))
                .unwrap();
        let opt = Optimizer::new(OptimizerKind::Sgd, 1e-2);
        let corpus = MarkovCorpus::new(&cfg, 12);
        let state = capture_train_state(&mut *eng, &opt, &corpus, 0).unwrap();
        let path = tmp("trainstate-trunc");
        save_train_state(&state, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 3]).unwrap();
        let err = load_train_state(&cfg, &path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        std::fs::remove_file(path).ok();
    }
}
