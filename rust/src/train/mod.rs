//! Training: optimizers (SGD / momentum / Adam over engine-owned shards),
//! a learnable synthetic Markov corpus, and the end-to-end loop.

pub mod checkpoint;
pub mod corpus;
pub mod optimizer;
pub mod schedule;
pub mod trainer;

pub use checkpoint::{
    capture_train_state, load_params, load_train_state, restore_train_state, save_params,
    save_train_state, AsyncCheckpointer, CkptStats, TrainState,
};
pub use corpus::{CorpusState, MarkovCorpus};
pub use optimizer::Optimizer;
pub use schedule::{grad_norm, LrSchedule};
pub use trainer::{train, train_with, TrainReport};
