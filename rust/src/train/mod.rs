//! Training: optimizers (SGD / momentum / Adam over engine-owned shards),
//! a learnable synthetic Markov corpus, and the end-to-end loop.

pub mod checkpoint;
pub mod corpus;
pub mod optimizer;
pub mod schedule;
pub mod trainer;

pub use checkpoint::{load_params, save_params};
pub use corpus::MarkovCorpus;
pub use optimizer::Optimizer;
pub use schedule::{grad_norm, LrSchedule};
pub use trainer::{train, TrainReport};
