//! Learning-rate schedules + gradient clipping — the standard training
//! controls a framework user expects around the paper's engines.

/// LR as a function of the 0-based step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    Constant { lr: f32 },
    /// Linear warmup to `peak` over `warmup` steps, then cosine decay to
    /// `floor` at `total` steps (GPT-style).
    WarmupCosine { peak: f32, floor: f32, warmup: usize, total: usize },
    /// Inverse-sqrt after warmup (the Transformer original).
    InverseSqrt { peak: f32, warmup: usize },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f32 {
        match *self {
            LrSchedule::Constant { lr } => lr,
            LrSchedule::WarmupCosine { peak, floor, warmup, total } => {
                if warmup > 0 && step < warmup {
                    return peak * (step + 1) as f32 / warmup as f32;
                }
                let total = total.max(warmup + 1);
                let t = ((step - warmup) as f32 / (total - warmup) as f32).min(1.0);
                floor + 0.5 * (peak - floor) * (1.0 + (std::f32::consts::PI * t).cos())
            }
            LrSchedule::InverseSqrt { peak, warmup } => {
                let w = warmup.max(1) as f32;
                if step < warmup {
                    peak * (step + 1) as f32 / w
                } else {
                    peak * (w / (step + 1) as f32).sqrt()
                }
            }
        }
    }

    pub fn parse(s: &str, lr: f32, steps: usize) -> Option<LrSchedule> {
        Some(match s {
            "constant" => LrSchedule::Constant { lr },
            "cosine" | "warmup-cosine" => LrSchedule::WarmupCosine {
                peak: lr,
                floor: lr / 10.0,
                warmup: (steps / 20).max(1),
                total: steps,
            },
            "inverse-sqrt" => {
                LrSchedule::InverseSqrt { peak: lr, warmup: (steps / 20).max(1) }
            }
            _ => return None,
        })
    }
}

/// Global gradient norm over an engine's owned grads. For sharded
/// engines each worker owns a disjoint partition, so the sum of squared
/// shard norms IS (up to the replicated params, which are counted per
/// worker as per-rank clipping implementations do) the model norm.
/// Clipping itself folds the scale into the optimizer's lr —
/// `Optimizer::step_clipped`.
pub fn grad_norm(engine: &mut dyn crate::parallel::Engine) -> f32 {
    let mut sq = 0.0f64;
    engine.visit_owned(&mut |_p, g| {
        for v in &g.data {
            sq += (*v as f64) * (*v as f64);
        }
    });
    sq.sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant { lr: 0.1 };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(1000), 0.1);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = LrSchedule::WarmupCosine { peak: 1.0, floor: 0.1, warmup: 10, total: 110 };
        assert!(s.at(0) < s.at(5));
        assert!((s.at(9) - 1.0).abs() < 0.01);
        // decays monotonically after warmup
        assert!(s.at(50) < s.at(10));
        assert!(s.at(109) < s.at(50));
        // lands on the floor
        assert!((s.at(109) - 0.1).abs() < 0.01);
        // never below floor after total
        assert!(s.at(1000) >= 0.1 - 1e-6);
    }

    #[test]
    fn inverse_sqrt_decays() {
        let s = LrSchedule::InverseSqrt { peak: 1.0, warmup: 4 };
        assert!((s.at(3) - 1.0).abs() < 1e-6);
        assert!(s.at(15) < s.at(4));
        assert!((s.at(15) - (4.0f32 / 16.0).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn parse_names() {
        assert!(LrSchedule::parse("constant", 1e-3, 100).is_some());
        assert!(LrSchedule::parse("cosine", 1e-3, 100).is_some());
        assert!(LrSchedule::parse("inverse-sqrt", 1e-3, 100).is_some());
        assert!(LrSchedule::parse("nope", 1e-3, 100).is_none());
    }

    #[test]
    fn grad_norm_measures_owned_shards() {
        use crate::config::Strategy;
        use crate::parallel::{build_engine, Batch, EngineOpts, ExecKind};
        use crate::util::rng::Rng;
        let cfg = crate::config::presets::get("tiny").unwrap();
        let b = Batch::synth(&cfg, 4, &mut Rng::new(1));
        let mut e = build_engine(
            &EngineOpts::new("tiny", Strategy::RtpInplace, 2, 4).exec(ExecKind::Oracle),
        )
        .unwrap();
        e.step(&b).unwrap();
        let norm = grad_norm(&mut *e);
        assert!(norm > 0.0 && norm.is_finite());
        // and the norm is engine-invariant (owned partitions cover the
        // model exactly once, replicated params aside)
        let mut s = build_engine(
            &EngineOpts::new("tiny", Strategy::Single, 1, 4).exec(ExecKind::Oracle),
        )
        .unwrap();
        s.step(&b).unwrap();
        let norm_single = grad_norm(&mut *s);
        assert!(
            (norm - norm_single).abs() / norm_single < 0.3,
            "rtp {norm} vs single {norm_single}"
        );
    }
}
