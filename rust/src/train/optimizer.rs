//! Optimizers over engine-owned parameter shards.
//!
//! Every engine exposes its OWNED (param, grad) pairs through
//! `Engine::visit_owned` in a deterministic order; the optimizer keeps its
//! state aligned to that order. Because SGD/momentum/Adam are elementwise,
//! updating shards is exactly equivalent to updating the assembled model —
//! which is what makes the multi-step engine-equivalence tests possible.

use crate::config::OptimizerKind;
use crate::memory::tracker::MemCategory;
use crate::parallel::Engine;

enum Slot {
    Sgd,
    Momentum(Vec<f32>),
    Adam { m: Vec<f32>, v: Vec<f32> },
}

pub struct Optimizer {
    pub kind: OptimizerKind,
    pub lr: f32,
    step: u64,
    state: Vec<Slot>,
}

const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const EPS: f32 = 1e-8;
const MOMENTUM: f32 = 0.9;

impl Optimizer {
    pub fn new(kind: OptimizerKind, lr: f32) -> Self {
        Optimizer { kind, lr, step: 0, state: Vec::new() }
    }

    /// Register the optimizer-state residency with every worker's tracker
    /// (state_factor × resident weight bytes — the Table-1 style
    /// accounting the capacity figures need). Call once after engine
    /// construction.
    pub fn attach(&self, engine: &mut dyn Engine) -> anyhow::Result<()> {
        let factor = self.kind.state_factor() as u64;
        if factor == 0 {
            return Ok(());
        }
        let n = engine.ctx().cluster.n();
        for w in 0..n {
            let wbytes = engine.ctx().cluster.workers[w].tracker.live_of(MemCategory::Weights);
            engine
                .ctx_mut()
                .cluster
                .tracker(w)
                .alloc(MemCategory::OptState, factor * wbytes)?;
        }
        Ok(())
    }

    /// The number of updates applied so far (Adam's bias-correction `t`).
    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Restore the update counter from a checkpoint so Adam's bias
    /// correction continues exactly where the interrupted run left off.
    pub fn set_step_count(&mut self, t: u64) {
        self.step = t;
    }

    /// How many per-parameter moment vectors this kind keeps (SGD 0,
    /// momentum 1, Adam 2 — the same multiplier as `state_factor`).
    pub fn moment_count(&self) -> usize {
        self.kind.state_factor()
    }

    /// Checkpoint staging: overwrite the engine's OWNED param tensors
    /// with moment `k`'s values (zeros where no state exists yet, e.g. an
    /// optimizer that never stepped). The engine's own `gather_params`
    /// then reassembles the FULL moment across any sharding layout —
    /// moments shard exactly like the params they track. The caller must
    /// restore the live weights afterwards via `Engine::load_full`.
    pub fn stage_moment_into_params(&self, engine: &mut dyn Engine, k: usize) {
        let state = &self.state;
        let mut i = 0;
        engine.visit_owned(&mut |p, _| {
            let src: Option<&[f32]> = match state.get(i) {
                Some(Slot::Momentum(m)) if k == 0 => Some(m),
                Some(Slot::Adam { m, .. }) if k == 0 => Some(m),
                Some(Slot::Adam { v, .. }) if k == 1 => Some(v),
                _ => None,
            };
            match src {
                Some(s) => p.data.copy_from_slice(s),
                None => p.data.fill(0.0),
            }
            i += 1;
        });
    }

    /// Checkpoint restore, the inverse of `stage_moment_into_params`:
    /// after the full moment was re-sharded into the engine's params via
    /// `Engine::load_full`, copy each owned shard into moment `k`.
    /// Creates state slots on first touch so a fresh optimizer hydrates
    /// at any world size.
    pub fn load_moment_from_params(&mut self, engine: &mut dyn Engine, k: usize) {
        let kind = self.kind;
        let state = &mut self.state;
        let mut i = 0;
        engine.visit_owned(&mut |p, _| {
            if state.len() == i {
                state.push(match kind {
                    OptimizerKind::Sgd => Slot::Sgd,
                    OptimizerKind::Momentum => Slot::Momentum(vec![0.0; p.data.len()]),
                    OptimizerKind::Adam => Slot::Adam {
                        m: vec![0.0; p.data.len()],
                        v: vec![0.0; p.data.len()],
                    },
                });
            }
            match &mut state[i] {
                Slot::Momentum(m) if k == 0 => m.copy_from_slice(&p.data),
                Slot::Adam { m, .. } if k == 0 => m.copy_from_slice(&p.data),
                Slot::Adam { v, .. } if k == 1 => v.copy_from_slice(&p.data),
                _ => {}
            }
            i += 1;
        });
    }

    /// `step` with global-norm clipping: the clip factor folds into the
    /// lr for this update (mathematically identical to scaling the grads,
    /// for SGD; for Adam it is the standard lr-scaling approximation).
    /// Returns the pre-clip gradient norm.
    pub fn step_clipped(&mut self, engine: &mut dyn Engine, max_norm: f32) -> f32 {
        let norm = super::schedule::grad_norm(engine);
        let saved = self.lr;
        if norm > max_norm && norm > 0.0 {
            self.lr *= max_norm / norm;
        }
        self.step(engine);
        self.lr = saved;
        norm
    }

    /// Apply one update over the engine's owned pairs. The engine is
    /// expected to hold fully-reduced gradients (i.e. `step()` ran).
    pub fn step(&mut self, engine: &mut dyn Engine) {
        self.step += 1;
        let t = self.step;
        let (kind, lr) = (self.kind, self.lr);
        let state = &mut self.state;
        let mut i = 0;
        engine.visit_owned(&mut |p, g| {
            if state.len() == i {
                state.push(match kind {
                    OptimizerKind::Sgd => Slot::Sgd,
                    OptimizerKind::Momentum => Slot::Momentum(vec![0.0; p.data.len()]),
                    OptimizerKind::Adam => Slot::Adam {
                        m: vec![0.0; p.data.len()],
                        v: vec![0.0; p.data.len()],
                    },
                });
            }
            match &mut state[i] {
                Slot::Sgd => {
                    for (w, gv) in p.data.iter_mut().zip(&g.data) {
                        *w -= lr * gv;
                    }
                }
                Slot::Momentum(buf) => {
                    for ((w, gv), m) in p.data.iter_mut().zip(&g.data).zip(buf.iter_mut()) {
                        *m = MOMENTUM * *m + gv;
                        *w -= lr * *m;
                    }
                }
                Slot::Adam { m, v } => {
                    let bc1 = 1.0 - BETA1.powi(t as i32);
                    let bc2 = 1.0 - BETA2.powi(t as i32);
                    for ((w, gv), (mm, vv)) in
                        p.data.iter_mut().zip(&g.data).zip(m.iter_mut().zip(v.iter_mut()))
                    {
                        *mm = BETA1 * *mm + (1.0 - BETA1) * gv;
                        *vv = BETA2 * *vv + (1.0 - BETA2) * gv * gv;
                        let mhat = *mm / bc1;
                        let vhat = *vv / bc2;
                        *w -= lr * mhat / (vhat.sqrt() + EPS);
                    }
                }
            }
            i += 1;
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Strategy;
    use crate::parallel::{build_engine, Batch, EngineOpts, ExecKind};
    use crate::util::rng::Rng;

    fn batch(seed: u64) -> Batch {
        let cfg = crate::config::presets::get("tiny").unwrap();
        Batch::synth(&cfg, 4, &mut Rng::new(seed))
    }

    /// Elementwise optimizers commute with sharding: training K steps on
    /// any engine must yield the same final params as on single.
    fn check_training_equivalence(strategy: Strategy, kind: OptimizerKind) {
        let steps = 3;
        let mut single = build_engine(
            &EngineOpts::new("tiny", Strategy::Single, 1, 4).exec(ExecKind::Oracle),
        )
        .unwrap();
        let mut eng =
            build_engine(&EngineOpts::new("tiny", strategy, 2, 4).exec(ExecKind::Oracle))
                .unwrap();
        let mut opt_a = Optimizer::new(kind, 1e-2);
        let mut opt_b = Optimizer::new(kind, 1e-2);
        for s in 0..steps {
            let b = batch(100 + s);
            single.zero_grads();
            single.step(&b).unwrap();
            opt_a.step(&mut *single);
            eng.zero_grads();
            eng.step(&b).unwrap();
            opt_b.step(&mut *eng);
        }
        single
            .gather_params()
            .allclose(&eng.gather_params(), 5e-3)
            .unwrap_or_else(|e| panic!("{strategy} {kind:?}: diverged: {e}"));
    }

    #[test]
    fn sgd_training_matches_single() {
        for s in [Strategy::Ddp, Strategy::RtpInplace, Strategy::Fsdp, Strategy::MegatronTp] {
            check_training_equivalence(s, OptimizerKind::Sgd);
        }
    }

    #[test]
    fn adam_training_matches_single() {
        for s in [Strategy::Ddp, Strategy::RtpOutOfPlace] {
            check_training_equivalence(s, OptimizerKind::Adam);
        }
    }

    #[test]
    fn momentum_training_matches_single() {
        check_training_equivalence(Strategy::RtpInplace, OptimizerKind::Momentum);
    }

    #[test]
    fn sgd_reduces_loss_on_repeated_batch() {
        let mut e = build_engine(
            &EngineOpts::new("tiny", Strategy::RtpInplace, 2, 4).exec(ExecKind::Oracle),
        )
        .unwrap();
        let mut opt = Optimizer::new(OptimizerKind::Adam, 1e-2);
        let b = batch(5);
        let mut first = 0.0;
        let mut last = 0.0;
        for s in 0..8 {
            e.zero_grads();
            let loss = e.step(&b).unwrap();
            if s == 0 {
                first = loss;
            }
            last = loss;
            opt.step(&mut *e);
        }
        assert!(last < 0.7 * first, "no learning: {first} -> {last}");
    }

    #[test]
    fn clipped_step_bounds_update() {
        let mut e = build_engine(
            &EngineOpts::new("tiny", Strategy::Ddp, 2, 4).exec(ExecKind::Oracle),
        )
        .unwrap();
        let b = batch(21);
        e.step(&b).unwrap();
        let before = e.gather_params();
        let mut opt = Optimizer::new(OptimizerKind::Sgd, 1.0); // huge lr
        let norm = opt.step_clipped(&mut *e, 1e-3);
        assert!(norm > 1e-3, "test needs a clipping grad");
        let after = e.gather_params();
        // update magnitude == lr * clipped norm <= lr * max_norm (per
        // tensor it is strictly smaller)
        let delta = after.max_abs_diff(&before);
        assert!(delta <= 1.1e-3, "clip failed: delta {delta}");
        // lr restored
        assert_eq!(opt.lr, 1.0);
    }

    #[test]
    fn attach_tracks_optimizer_state() {
        let mut e = build_engine(
            &EngineOpts::new("tiny", Strategy::Ddp, 2, 4).exec(ExecKind::Virtual),
        )
        .unwrap();
        let opt = Optimizer::new(OptimizerKind::Adam, 1e-3);
        opt.attach(&mut *e).unwrap();
        let w = e.ctx().cluster.workers[0].tracker.live_of(MemCategory::Weights);
        let s = e.ctx().cluster.workers[0].tracker.live_of(MemCategory::OptState);
        assert_eq!(s, 2 * w);
    }
}
