//! Synthetic training corpus: a sparse Markov chain over the vocabulary.
//!
//! Each token has a small successor set (fan-out 4) with skewed
//! probabilities, so a language model can actually LEARN the structure —
//! the end-to-end example's loss curve has signal, unlike uniform noise
//! whose optimal loss is ln(V) regardless of training.

use crate::config::ModelCfg;
use crate::parallel::Batch;
use crate::tensor::IntTensor;
use crate::util::rng::Rng;

const FANOUT: usize = 4;
/// Probability mass of the dominant successor.
const P_HEAD: f64 = 0.7;

pub struct MarkovCorpus {
    vocab: usize,
    seq: usize,
    /// The construction seed (rides checkpoints so the successor table —
    /// a pure function of it — can be re-derived at resume).
    seed: u64,
    /// successors[t] = the FANOUT candidate next-tokens of t.
    successors: Vec<[usize; FANOUT]>,
    rng: Rng,
    state: usize,
}

/// The corpus's checkpointable sampling cursor: the successor table is a
/// pure function of `seed`, so only the live RNG state and chain position
/// ride the checkpoint. Restoring continues the exact batch sequence an
/// uninterrupted run would have produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CorpusState {
    pub seed: u64,
    pub rng: [u64; 4],
    pub state: u64,
}

impl MarkovCorpus {
    pub fn new(cfg: &ModelCfg, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5EED_C0DE);
        let successors = (0..cfg.vocab)
            .map(|_| {
                let mut s = [0usize; FANOUT];
                for v in s.iter_mut() {
                    *v = rng.below(cfg.vocab);
                }
                s
            })
            .collect();
        MarkovCorpus { vocab: cfg.vocab, seq: cfg.seq, seed, successors, rng, state: 0 }
    }

    /// The checkpointable cursor (see [`CorpusState`]).
    pub fn snapshot(&self) -> CorpusState {
        CorpusState {
            seed: self.seed,
            rng: self.rng.state(),
            state: self.state as u64,
        }
    }

    /// Rebuild the corpus mid-stream from a [`MarkovCorpus::snapshot`].
    pub fn restore(cfg: &ModelCfg, s: CorpusState) -> Self {
        let mut c = MarkovCorpus::new(cfg, s.seed);
        c.rng = Rng::from_state(s.rng);
        c.state = s.state as usize;
        c
    }

    fn next_token(&mut self) -> usize {
        let succ = &self.successors[self.state];
        let u = self.rng.uniform();
        // P_HEAD on succ[0], the rest split over succ[1..] + noise floor
        let next = if u < P_HEAD {
            succ[0]
        } else if u < 0.95 {
            succ[1 + self.rng.below(FANOUT - 1)]
        } else {
            self.rng.below(self.vocab)
        };
        self.state = next;
        next
    }

    /// Next global batch: ids [B, S] with next-token targets.
    pub fn next_batch(&mut self, global_batch: usize) -> Batch {
        let (b, s) = (global_batch, self.seq);
        let mut ids = vec![0i32; b * s];
        let mut targets = vec![0i32; b * s];
        for row in 0..b {
            // random restart per row keeps rows independent
            self.state = self.rng.below(self.vocab);
            let mut cur = self.state;
            for col in 0..s {
                ids[row * s + col] = cur as i32;
                let nxt = self.next_token();
                targets[row * s + col] = nxt as i32;
                cur = nxt;
            }
        }
        Batch {
            ids: IntTensor::from_vec(&[b, s], ids),
            targets: IntTensor::from_vec(&[b, s], targets),
        }
    }

    /// The most likely successor of `token` (ground truth for the
    /// `generate` example's accuracy metric).
    pub fn dominant_successor(&self, token: usize) -> usize {
        self.successors[token][0]
    }

    /// The entropy floor of the chain (per-token loss a perfect model
    /// converges to) — roughly -Σ p ln p of the successor distribution.
    pub fn entropy_floor(&self) -> f64 {
        let p_noise = 0.05 / self.vocab as f64;
        let p0 = P_HEAD + p_noise;
        let p_mid = (0.95 - P_HEAD) / (FANOUT - 1) as f64 + p_noise;
        let mut h = -p0 * p0.ln() - (FANOUT - 1) as f64 * p_mid * p_mid.ln();
        h -= 0.05 * p_noise.ln() * 0.0; // noise tail, negligible
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn batches_have_correct_shapes_and_range() {
        let cfg = presets::get("tiny").unwrap();
        let mut c = MarkovCorpus::new(&cfg, 1);
        let b = c.next_batch(4);
        assert_eq!(b.ids.shape, vec![4, cfg.seq]);
        assert_eq!(b.targets.shape, vec![4, cfg.seq]);
        for v in b.ids.data.iter().chain(&b.targets.data) {
            assert!((0..cfg.vocab as i32).contains(v));
        }
    }

    #[test]
    fn targets_are_next_tokens() {
        let cfg = presets::get("tiny").unwrap();
        let mut c = MarkovCorpus::new(&cfg, 2);
        let b = c.next_batch(2);
        let s = cfg.seq;
        for row in 0..2 {
            for col in 0..s - 1 {
                assert_eq!(
                    b.targets.data[row * s + col],
                    b.ids.data[row * s + col + 1],
                    "target must be the next input token"
                );
            }
        }
    }

    #[test]
    fn chain_is_predictable_not_uniform() {
        // the dominant successor must appear far more often than 1/V
        let cfg = presets::get("tiny").unwrap();
        let mut c = MarkovCorpus::new(&cfg, 3);
        let b = c.next_batch(16);
        let s = cfg.seq;
        let mut hits = 0;
        let mut total = 0;
        for row in 0..16 {
            for col in 0..s {
                let cur = b.ids.data[row * s + col] as usize;
                let tgt = b.targets.data[row * s + col] as usize;
                if c.successors[cur][0] == tgt {
                    hits += 1;
                }
                total += 1;
            }
        }
        let rate = hits as f64 / total as f64;
        assert!(rate > 0.5, "head-successor rate {rate}");
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = presets::get("tiny").unwrap();
        let a = MarkovCorpus::new(&cfg, 9).next_batch(2);
        let b = MarkovCorpus::new(&cfg, 9).next_batch(2);
        assert_eq!(a.ids.data, b.ids.data);
        let c = MarkovCorpus::new(&cfg, 10).next_batch(2);
        assert_ne!(a.ids.data, c.ids.data);
    }

    #[test]
    fn entropy_floor_is_below_uniform() {
        let cfg = presets::get("tiny").unwrap();
        let c = MarkovCorpus::new(&cfg, 1);
        assert!(c.entropy_floor() < (cfg.vocab as f64).ln());
        assert!(c.entropy_floor() > 0.0);
    }
}
