//! The training loop: engine + optimizer + corpus, with loss-curve and
//! throughput reporting (the end-to-end validation driver).

use anyhow::Result;

use crate::config::TrainCfg;
use crate::parallel::Engine;
use crate::util::bytes::human;

use super::corpus::MarkovCorpus;
use super::optimizer::Optimizer;

#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub wall_s: f64,
    pub steps: usize,
    pub tokens_per_s: f64,
    pub peak_bytes_per_worker: u64,
}

impl TrainReport {
    /// Mean loss over the first / last k steps — the smoke signal the
    /// integration tests assert on.
    pub fn head_tail_means(&self, k: usize) -> (f32, f32) {
        let k = k.min(self.losses.len());
        let head: f32 = self.losses[..k].iter().sum::<f32>() / k as f32;
        let tail: f32 =
            self.losses[self.losses.len() - k..].iter().sum::<f32>() / k as f32;
        (head, tail)
    }
}

pub fn train(
    engine: &mut dyn Engine,
    opt: &mut Optimizer,
    corpus: &mut MarkovCorpus,
    tcfg: &TrainCfg,
    global_batch: usize,
    quiet: bool,
) -> Result<TrainReport> {
    train_with(engine, opt, corpus, tcfg, global_batch, quiet, &mut |_, _, _, _| Ok(()))
}

/// [`train`] with a per-step hook, called after the optimizer step with
/// `(completed_steps, engine, opt, corpus)` — the seam `rtp train
/// --elastic` uses to capture periodic async-checkpoint snapshots
/// without the loop itself knowing about checkpointing.
pub fn train_with(
    engine: &mut dyn Engine,
    opt: &mut Optimizer,
    corpus: &mut MarkovCorpus,
    tcfg: &TrainCfg,
    global_batch: usize,
    quiet: bool,
    after_step: &mut dyn FnMut(
        usize,
        &mut dyn Engine,
        &mut Optimizer,
        &MarkovCorpus,
    ) -> Result<()>,
) -> Result<TrainReport> {
    opt.attach(engine)?;
    let seq = engine.ctx().cfg.seq;
    let start = std::time::Instant::now();
    let mut losses = Vec::with_capacity(tcfg.steps);
    for step in 0..tcfg.steps {
        let batch = corpus.next_batch(global_batch);
        engine.zero_grads();
        let loss = engine.step(&batch)?;
        opt.step(engine);
        losses.push(loss);
        after_step(step + 1, engine, opt, corpus)?;
        if !quiet && (step % tcfg.log_every == 0 || step + 1 == tcfg.steps) {
            let elapsed = start.elapsed().as_secs_f64();
            let wps = ((step + 1) * global_batch * seq) as f64 / elapsed;
            println!(
                "step {step:>5}  loss {loss:.4}  {wps:>9.0} tok/s  peak/worker {}",
                human(engine.ctx().cluster.max_peak())
            );
        }
    }
    let wall_s = start.elapsed().as_secs_f64();
    Ok(TrainReport {
        steps: tcfg.steps,
        tokens_per_s: (tcfg.steps * global_batch * seq) as f64 / wall_s,
        wall_s,
        peak_bytes_per_worker: engine.ctx().cluster.max_peak(),
        losses,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OptimizerKind, Strategy};
    use crate::parallel::{build_engine, EngineOpts, ExecKind};

    #[test]
    fn loss_decreases_on_markov_corpus() {
        let mut engine = build_engine(
            &EngineOpts::new("tiny", Strategy::RtpInplace, 2, 4).exec(ExecKind::Oracle),
        )
        .unwrap();
        let cfg = crate::config::presets::get("tiny").unwrap();
        let mut corpus = MarkovCorpus::new(&cfg, 42);
        let mut opt = Optimizer::new(OptimizerKind::Adam, 5e-3);
        let tcfg = TrainCfg { steps: 40, log_every: 1000, ..TrainCfg::default() };
        let report = train(&mut *engine, &mut opt, &mut corpus, &tcfg, 4, true).unwrap();
        let (head, tail) = report.head_tail_means(5);
        assert!(
            tail < 0.85 * head,
            "loss did not decrease: head {head} tail {tail}"
        );
        assert!(report.tokens_per_s > 0.0);
    }

    #[test]
    fn same_seed_same_curve_across_engines() {
        let cfg = crate::config::presets::get("tiny").unwrap();
        let tcfg = TrainCfg { steps: 5, log_every: 1000, ..TrainCfg::default() };
        let mut curves = Vec::new();
        for strategy in [Strategy::Single, Strategy::Ddp, Strategy::RtpInplace] {
            let mut engine = build_engine(
                &EngineOpts::new("tiny", strategy, 2, 4).exec(ExecKind::Oracle),
            )
            .unwrap();
            let mut corpus = MarkovCorpus::new(&cfg, 42);
            let mut opt = Optimizer::new(OptimizerKind::Sgd, 1e-2);
            let r = train(&mut *engine, &mut opt, &mut corpus, &tcfg, 4, true).unwrap();
            curves.push(r.losses);
        }
        for step in 0..curves[0].len() {
            for c in &curves[1..] {
                assert!(
                    (c[step] - curves[0][step]).abs() < 2e-3 * curves[0][step].abs().max(1.0),
                    "curves diverge at step {step}: {} vs {}",
                    c[step],
                    curves[0][step]
                );
            }
        }
    }
}
