//! Configuration: model presets (paper Table 2 + runtime presets), the
//! parallelism strategy selection and training hyperparameters.

pub mod presets;

use std::fmt;

/// GPT-style transformer hyperparameters — mirrors
/// `python/compile/presets.py::ModelConfig` (kept in sync by
/// `presets::tests::matches_python_manifest`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub heads: usize,
    pub layers: usize,
    pub seq: usize,
    pub ffn: usize,
    /// 0 = dense MLP; otherwise MoE with this many experts.
    pub experts: usize,
    pub expert_ffn: usize,
}

impl ModelCfg {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    pub fn is_moe(&self) -> bool {
        self.experts > 0
    }

    /// Parameter count of the dense variant (untied LM head); mirrors
    /// python `params_dense`.
    pub fn params_dense(&self) -> usize {
        let h = self.hidden;
        let f = self.ffn;
        let emb = self.vocab * h + self.seq * h;
        let per_layer =
            3 * h * h + 3 * h + h * h + h + 2 * h * f + f + h + 4 * h;
        emb + self.layers * per_layer + h * self.vocab + 2 * h
    }

    /// Parameter count including MoE experts (router + E expert FFNs +
    /// one shared output bias replacing the dense MLP in every layer) —
    /// mirrors `model::params::ModelParams` exactly.
    pub fn params_total(&self) -> usize {
        if !self.is_moe() {
            return self.params_dense();
        }
        let h = self.hidden;
        let fe = self.expert_ffn;
        // dense mlp w1+b1+w2 (b2 stays in both variants)
        let dense_mlp = 2 * h * self.ffn + self.ffn;
        // router wr [H,E] + per-expert {w1 [H,Fe], b1 [Fe], w2 [Fe,H]}
        let moe = h * self.experts + self.experts * (2 * h * fe + fe);
        self.params_dense() - self.layers * dense_mlp + self.layers * moe
    }

    /// Weight bytes (f32).
    pub fn weight_bytes(&self) -> u64 {
        (self.params_total() * 4) as u64
    }

    /// Activation bytes for one sample's forward residency under the
    /// recompute policy the engines implement: per layer the saved inputs
    /// (x, a, x1, m) = 4 x [S, H], plus embedding output and final logits.
    pub fn activation_bytes_per_sample(&self) -> u64 {
        let sh = self.seq * self.hidden;
        let per_layer = 4 * sh;
        let logits = self.seq * self.vocab;
        (4 * (sh + self.layers * per_layer + sh + logits)) as u64
    }
}

/// Which parallel engine to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// The idealized computer: one device, whole model, whole batch.
    Single,
    /// Distributed data parallel (full replica + gradient allreduce).
    Ddp,
    /// Fully-sharded data parallel (unit allgather / reduce-scatter).
    Fsdp,
    /// Megatron-style static tensor parallelism.
    MegatronTp,
    /// The paper: rotated tensor parallelism, blocking in-place rotation.
    RtpInplace,
    /// The paper: rotated tensor parallelism, double-buffered overlap.
    RtpOutOfPlace,
}

impl Strategy {
    pub const ALL: [Strategy; 6] = [
        Strategy::Single,
        Strategy::Ddp,
        Strategy::Fsdp,
        Strategy::MegatronTp,
        Strategy::RtpInplace,
        Strategy::RtpOutOfPlace,
    ];

    pub fn parse(s: &str) -> Option<Strategy> {
        Some(match s {
            "single" => Strategy::Single,
            "ddp" | "dp" => Strategy::Ddp,
            "fsdp" => Strategy::Fsdp,
            "tp" | "megatron" | "megatron-tp" => Strategy::MegatronTp,
            "rtp" | "rtp-inplace" => Strategy::RtpInplace,
            "rtp-outofplace" | "rtp-oop" | "rtp-out" => Strategy::RtpOutOfPlace,
            _ => return None,
        })
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Strategy::Single => "single",
            Strategy::Ddp => "ddp",
            Strategy::Fsdp => "fsdp",
            Strategy::MegatronTp => "megatron-tp",
            Strategy::RtpInplace => "rtp-inplace",
            Strategy::RtpOutOfPlace => "rtp-outofplace",
        };
        f.write_str(s)
    }
}

/// Parallel-execution parameters.
#[derive(Debug, Clone)]
pub struct ParallelCfg {
    pub strategy: Strategy,
    /// Worker (device) count N — the paper's partition factor.
    pub workers: usize,
    /// Global batch; each DP-style worker gets `global_batch / workers`.
    pub global_batch: usize,
}

impl ParallelCfg {
    pub fn local_batch(&self) -> usize {
        match self.strategy {
            // Megatron TP replicates activations: full batch everywhere.
            Strategy::MegatronTp => self.global_batch,
            Strategy::Single => self.global_batch,
            _ => {
                assert!(
                    self.global_batch % self.workers == 0,
                    "global batch {} not divisible by {} workers",
                    self.global_batch,
                    self.workers
                );
                self.global_batch / self.workers
            }
        }
    }

    /// Weight-partition factor P for the shard artifacts this strategy
    /// executes (1 = full weights).
    pub fn weight_partition(&self) -> usize {
        match self.strategy {
            Strategy::Single | Strategy::Ddp | Strategy::Fsdp => 1,
            Strategy::MegatronTp
            | Strategy::RtpInplace
            | Strategy::RtpOutOfPlace => self.workers,
        }
    }
}

/// Training hyperparameters.
#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub steps: usize,
    pub lr: f32,
    pub optimizer: OptimizerKind,
    pub seed: u64,
    /// Log every k steps.
    pub log_every: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    /// SGD with momentum 0.9.
    Momentum,
    Adam,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "sgd" => OptimizerKind::Sgd,
            "momentum" => OptimizerKind::Momentum,
            "adam" => OptimizerKind::Adam,
            _ => return None,
        })
    }

    /// Optimizer state multiplier over W (Table-1 style accounting).
    pub fn state_factor(&self) -> usize {
        match self {
            OptimizerKind::Sgd => 0,
            OptimizerKind::Momentum => 1,
            OptimizerKind::Adam => 2,
        }
    }
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 50,
            lr: 1e-3,
            optimizer: OptimizerKind::Adam,
            seed: 42,
            log_every: 10,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrip() {
        for s in Strategy::ALL {
            assert_eq!(Strategy::parse(&s.to_string()), Some(s));
        }
        assert_eq!(Strategy::parse("nope"), None);
    }

    #[test]
    fn local_batch_by_strategy() {
        let mut p = ParallelCfg {
            strategy: Strategy::Ddp,
            workers: 4,
            global_batch: 8,
        };
        assert_eq!(p.local_batch(), 2);
        p.strategy = Strategy::MegatronTp;
        assert_eq!(p.local_batch(), 8);
        assert_eq!(p.weight_partition(), 4);
        p.strategy = Strategy::Fsdp;
        assert_eq!(p.weight_partition(), 1);
    }

    #[test]
    fn params_moe_exceeds_dense() {
        let mut m = presets::get("tiny").unwrap();
        let dense = m.params_total();
        m.experts = 4;
        m.expert_ffn = m.ffn;
        assert!(m.params_total() > dense);
    }
}
