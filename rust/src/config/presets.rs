//! Model presets — the rust mirror of `python/compile/presets.py`.
//!
//! Table-2 presets (paper evaluation models) are virtual-mode only; runtime
//! presets have AOT artifact sets. An integration test cross-checks these
//! dims against the manifest's embedded config.

use super::ModelCfg;

fn cfg(
    name: &str,
    vocab: usize,
    hidden: usize,
    heads: usize,
    layers: usize,
    seq: usize,
    ffn: usize,
) -> ModelCfg {
    ModelCfg {
        name: name.to_string(),
        vocab,
        hidden,
        heads,
        layers,
        seq,
        ffn,
        experts: 0,
        expert_ffn: 0,
    }
}

/// Paper Table 2 rows, in paper order.
pub fn table2() -> Vec<ModelCfg> {
    vec![
        cfg("gpt2-117m", 50257, 768, 16, 12, 512, 3072),
        cfg("bert-large-340m", 30522, 1024, 16, 24, 512, 4096),
        cfg("gpt2-500m", 50257, 1280, 16, 20, 1024, 5120),
        cfg("gpt2-large-774m", 50257, 1280, 16, 32, 1024, 5120),
        cfg("gpt2-xl-1.5b", 50257, 1600, 16, 48, 1024, 6400),
        cfg("gpt2-neo-2.7b", 50257, 2560, 16, 32, 1024, 10240),
    ]
}

/// All presets (Table 2 + runtime).
pub fn get(name: &str) -> Option<ModelCfg> {
    let runtime = match name {
        "tiny" => Some(cfg("tiny", 128, 32, 4, 2, 16, 128)),
        // `tiny` with 8 heads (head_dim 4): every dimension divides 8, so
        // the head-sharded engines (TP/RTP) run at N=8 in fast tests —
        // the launcher-equivalence matrix uses it.
        "tiny-wide" => Some(cfg("tiny-wide", 128, 32, 8, 2, 16, 128)),
        "tiny-moe" => {
            let mut m = cfg("tiny-moe", 128, 32, 4, 2, 16, 128);
            m.experts = 4;
            m.expert_ffn = 128;
            Some(m)
        }
        "e2e-small" => Some(cfg("e2e-small", 8192, 512, 8, 8, 64, 2048)),
        "e2e-100m" => Some(cfg("e2e-100m", 16384, 768, 12, 12, 64, 3072)),
        // The paper §5.3's "GPT-up-to-A100": a GPT2-500M-shaped model that
        // just fits one 80 GB device at batch 8 (see fig9_dedup bench).
        "gpt-up-to-a100" => Some(cfg("gpt-up-to-a100", 50257, 1536, 16, 40, 1024, 6144)),
        // MoE GPT2-500M (paper Figs 11/14): 8 experts, one per worker,
        // each expert the size of the dense FFN.
        "gpt2-500m-moe" => {
            let mut m = cfg("gpt2-500m-moe", 50257, 1280, 16, 20, 1024, 5120);
            m.experts = 8;
            m.expert_ffn = 5120;
            Some(m)
        }
        _ => None,
    };
    runtime.or_else(|| table2().into_iter().find(|m| m.name == name))
}

pub fn all_names() -> Vec<String> {
    let mut v: Vec<String> = table2().into_iter().map(|m| m.name).collect();
    for n in [
        "tiny",
        "tiny-wide",
        "tiny-moe",
        "e2e-small",
        "e2e-100m",
        "gpt-up-to-a100",
        "gpt2-500m-moe",
    ] {
        v.push(n.to_string());
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_param_counts_are_in_band() {
        // Paper names carry the approximate sizes; our untied-LM-head
        // counts must land within ~25% of the nameplate number.
        let expect = [
            ("gpt2-117m", 117e6, 0.45), // 117M nameplate ties the LM head
            ("bert-large-340m", 340e6, 0.30),
            ("gpt2-500m", 500e6, 0.30),
            ("gpt2-large-774m", 774e6, 0.30),
            ("gpt2-xl-1.5b", 1.5e9, 0.30),
            ("gpt2-neo-2.7b", 2.7e9, 0.30),
        ];
        for (name, nominal, tol) in expect {
            let p = get(name).unwrap().params_total() as f64;
            let rel = (p - nominal).abs() / nominal;
            assert!(rel < tol, "{name}: {p:.3e} vs nominal {nominal:.3e}");
        }
    }

    #[test]
    fn e2e_100m_is_roughly_100m() {
        let p = get("e2e-100m").unwrap().params_total() as f64;
        assert!((90e6..150e6).contains(&p), "{p}");
    }

    #[test]
    fn tiny_dims_divide_cleanly() {
        for name in ["tiny", "tiny-wide", "tiny-moe", "e2e-small", "e2e-100m"] {
            let m = get(name).unwrap();
            for n in [2usize, 4] {
                if name.starts_with("tiny") {
                    assert_eq!(m.hidden % n, 0);
                    assert_eq!(m.heads % n, 0);
                    assert_eq!(m.ffn % n, 0);
                    assert_eq!(m.vocab % n, 0);
                }
            }
            assert_eq!(m.hidden % m.heads, 0);
        }
        // tiny-wide exists so the head-sharded engines run at N=8
        let w = get("tiny-wide").unwrap();
        for d in [w.hidden, w.heads, w.ffn, w.vocab] {
            assert_eq!(d % 8, 0, "tiny-wide must divide cleanly at N=8");
        }
    }

    #[test]
    fn unknown_preset_is_none() {
        assert!(get("gpt5").is_none());
    }

    #[test]
    fn moe_params_counted() {
        let m = get("tiny-moe").unwrap();
        assert!(m.params_total() > m.params_dense());
    }
}
