//! Mini benchmark harness + table/figure renderers (criterion substitute —
//! offline crate cache; DESIGN.md §2). Every `cargo bench` target prints
//! the paper's rows/series as ASCII and writes a CSV next to it under
//! `figures/`.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Time `f` with warmup; returns per-iteration stats in seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    Summary::of(&samples)
}

/// Simple fixed-width table printer.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (w, c) in widths.iter_mut().zip(r) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Write the table as CSV under `figures/<name>.csv`.
    pub fn write_csv(&self, name: &str) -> std::io::Result<PathBuf> {
        let dir = figures_dir();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for r in &self.rows {
            writeln!(f, "{}", r.join(","))?;
        }
        Ok(path)
    }
}

pub fn figures_dir() -> PathBuf {
    if let Ok(d) = std::env::var("RTP_FIGURES") {
        return PathBuf::from(d);
    }
    let local = PathBuf::from("figures");
    if local.exists() || std::fs::create_dir_all(&local).is_ok() {
        return local;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("figures")
}

/// Read-merge-write `figures/BENCH_overlap.json`. Several bench targets
/// contribute keys to the one gated overlap artifact (`hotpath` writes
/// the overlap/scheduler keys, `comm_microbench` the `transport_*`
/// ablation keys); each merges only its own keys so the targets can run
/// in either order — or alone — without clobbering the other's numbers.
/// An unreadable or non-object existing file is replaced, not appended.
pub fn merge_overlap_json(updates: BTreeMap<String, Json>) -> std::io::Result<PathBuf> {
    let dir = figures_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_overlap.json");
    let mut obj = match std::fs::read_to_string(&path) {
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Obj(m)) => m,
            _ => BTreeMap::new(),
        },
        Err(_) => BTreeMap::new(),
    };
    obj.extend(updates);
    std::fs::write(&path, format!("{}\n", Json::Obj(obj)))?;
    Ok(path)
}

/// ASCII horizontal bar chart — the figure renderer (one bar per row).
pub fn bar_chart(title: &str, rows: &[(String, f64)], unit: &str, width: usize) -> String {
    let max = rows.iter().map(|(_, v)| *v).fold(0.0, f64::max).max(1e-12);
    let label_w = rows.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("== {title} ==\n");
    for (label, v) in rows {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "{label:<label_w$} |{} {v:.3} {unit}\n",
            "#".repeat(n)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_positive_times() {
        let s = bench(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.n, 5);
        assert!(s.mean > 0.0);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn table_renders_and_aligns() {
        let mut t = Table::new("test", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("== test =="));
        assert!(r.contains("long-name"));
    }

    #[test]
    #[should_panic(expected = "table arity")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn bar_chart_scales_to_max() {
        let c = bar_chart(
            "capacity",
            &[("rtp".to_string(), 1.0), ("ddp".to_string(), 4.0)],
            "GiB",
            20,
        );
        assert!(c.contains("####################")); // full-width ddp bar
        assert!(c.contains("#####")); // quarter rtp bar
    }

    #[test]
    fn csv_written_to_figures() {
        let dir = std::env::temp_dir().join("rtp-fig-test");
        std::env::set_var("RTP_FIGURES", &dir);
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["1".into()]);
        let p = t.write_csv("unit_test_table").unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a\n1\n");

        // merge_overlap_json preserves foreign keys across two writers
        // (same env-var window as the csv check to keep RTP_FIGURES races
        // between parallel tests out of the picture)
        let mut first = BTreeMap::new();
        first.insert("alpha".to_string(), Json::Num(1.0));
        let path = merge_overlap_json(first).unwrap();
        let mut second = BTreeMap::new();
        second.insert("beta".to_string(), Json::Num(2.0));
        merge_overlap_json(second).unwrap();
        let merged = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(merged.get("alpha").as_f64(), Some(1.0));
        assert_eq!(merged.get("beta").as_f64(), Some(2.0));
        std::fs::remove_file(&path).unwrap();
        std::env::remove_var("RTP_FIGURES");
    }
}
