//! Hand-rolled CLI argument parsing (the offline crate cache has no clap —
//! DESIGN.md §2).
//!
//! Grammar: `rtp <subcommand> [--flag value]... [--switch]...`

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: BTreeSet<String>,
}

/// Switch names (no value) recognized by the parser.
const SWITCHES: &[&str] = &[
    "help", "quiet", "trace", "presets", "no-recycle", "no-capacity", "pallas",
    "elastic",
];

impl Args {
    pub fn parse<I: Iterator<Item = String>>(mut it: I) -> Result<Args> {
        let mut a = Args::default();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if SWITCHES.contains(&name) {
                    a.switches.insert(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    a.flags.insert(name.to_string(), v);
                }
            } else if a.subcommand.is_none() {
                a.subcommand = Some(tok);
            } else {
                bail!("unexpected positional argument {tok:?}");
            }
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a float, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.contains(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Result<Args> {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = parse(&["train", "--preset", "tiny", "--steps", "50", "--quiet"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("preset"), Some("tiny"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 50);
        assert!(a.switch("quiet"));
        assert!(!a.switch("trace"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["train"]).unwrap();
        assert_eq!(a.get_or("preset", "tiny"), "tiny");
        assert_eq!(a.f32_or("lr", 1e-3).unwrap(), 1e-3);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&["train", "--steps"]).is_err());
    }

    #[test]
    fn bad_int_is_error() {
        let a = parse(&["x", "--steps", "many"]).unwrap();
        assert!(a.usize_or("steps", 1).is_err());
    }

    #[test]
    fn extra_positional_is_error() {
        assert!(parse(&["a", "b"]).is_err());
    }
}
