//! Tiny CPU reference ops.
//!
//! NOT the compute path (that's the AOT HLO executables) — these exist so
//! unit/property tests of the comm + engine glue can run without artifacts,
//! and as an independent oracle for finite-difference checks.

use super::HostTensor;

/// C = A @ B for 2-D tensors. Naive triple loop — test-only.
pub fn matmul(a: &HostTensor, b: &HostTensor) -> HostTensor {
    assert_eq!(a.shape.len(), 2);
    assert_eq!(b.shape.len(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dim mismatch");
    let mut out = HostTensor::zeros(&[m, n]);
    for i in 0..m {
        for kk in 0..k {
            let av = a.data[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            let orow = &mut out.data[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// tanh-approximate GeLU, matching kernels/ref.py.
pub fn gelu(x: f32) -> f32 {
    let c = (2.0 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

/// Row softmax of a [R, C] tensor.
pub fn softmax_rows(x: &HostTensor) -> HostTensor {
    let c = x.last_dim();
    let mut out = x.clone();
    for row in out.data.chunks_mut(c) {
        let m = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut s = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            s += *v;
        }
        for v in row.iter_mut() {
            *v /= s;
        }
    }
    out
}

/// argmax along the last axis -> indices [R].
pub fn argmax_rows(x: &HostTensor) -> Vec<usize> {
    let c = x.last_dim();
    x.data
        .chunks(c)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_hand_value() {
        let a = HostTensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = HostTensor::from_vec(&[2, 2], vec![5., 6., 7., 8.]);
        assert_eq!(matmul(&a, &b).data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn gelu_known_points() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.1588).abs() < 1e-3);
    }

    #[test]
    fn softmax_normalizes() {
        let x = HostTensor::from_vec(&[2, 3], vec![1., 2., 3., 0., 0., 0.]);
        let s = softmax_rows(&x);
        for row in s.data.chunks(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        assert!((s.data[3] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn argmax_picks_max() {
        let x = HostTensor::from_vec(&[2, 3], vec![1., 5., 3., 9., 0., 2.]);
        assert_eq!(argmax_rows(&x), vec![1, 0]);
    }
}
