//! Host tensors: shaped f32 / i32 buffers.
//!
//! This is deliberately *not* an ndarray clone: engines only need shaped
//! storage plus the handful of cheap glue ops that live between AOT'd HLO
//! calls (concat/slice on the last axis for Output-Partition merges,
//! accumulation for sum-merges, bias reductions). All heavy math runs in
//! the PJRT executables.

pub mod ops;

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        HostTensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor { shape: vec![], data: vec![v] }
    }

    /// N(0, std) init (weight initialization).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let mut t = Self::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    /// Size of the last axis (1 for scalars).
    pub fn last_dim(&self) -> usize {
        self.shape.last().copied().unwrap_or(1)
    }

    /// Rows = product of all leading axes.
    pub fn rows(&self) -> usize {
        self.numel() / self.last_dim().max(1)
    }

    /// Elementwise accumulate: `self += other`.
    pub fn add_assign(&mut self, other: &HostTensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &HostTensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Broadcast-add a [C] vector over the last axis of [..., C].
    pub fn add_row_broadcast(&mut self, bias: &HostTensor) {
        let c = self.last_dim();
        assert_eq!(bias.shape, vec![c], "bias must be [last_dim]");
        for row in self.data.chunks_mut(c) {
            for (a, b) in row.iter_mut().zip(&bias.data) {
                *a += b;
            }
        }
    }

    /// Sum over all leading axes -> [C] (bias gradients).
    pub fn sum_leading(&self) -> HostTensor {
        let c = self.last_dim();
        let mut out = HostTensor::zeros(&[c]);
        for row in self.data.chunks(c) {
            for (o, v) in out.data.iter_mut().zip(row) {
                *o += v;
            }
        }
        out
    }

    /// Concatenate tensors along the LAST axis (Output-Partition merge).
    pub fn concat_last(parts: &[&HostTensor]) -> HostTensor {
        assert!(!parts.is_empty());
        let lead = &parts[0].shape[..parts[0].shape.len() - 1];
        let rows = parts[0].rows();
        let mut total_c = 0;
        for p in parts {
            assert_eq!(&p.shape[..p.shape.len() - 1], lead, "lead dims differ");
            total_c += p.last_dim();
        }
        let mut shape = lead.to_vec();
        shape.push(total_c);
        let mut out = HostTensor::zeros(&shape);
        for r in 0..rows {
            let mut off = 0;
            for p in parts {
                let c = p.last_dim();
                out.data[r * total_c + off..r * total_c + off + c]
                    .copy_from_slice(&p.data[r * c..(r + 1) * c]);
                off += c;
            }
        }
        out
    }

    /// Slice `[start, start+len)` of the LAST axis (Output-Partition split).
    pub fn slice_last(&self, start: usize, len: usize) -> HostTensor {
        let c = self.last_dim();
        assert!(start + len <= c, "slice_last out of range");
        let rows = self.rows();
        let mut shape = self.shape.clone();
        *shape.last_mut().unwrap() = len;
        let mut out = HostTensor::zeros(&shape);
        for r in 0..rows {
            out.data[r * len..(r + 1) * len]
                .copy_from_slice(&self.data[r * c + start..r * c + start + len]);
        }
        out
    }

    /// Write `part` into `[start, start+part.last_dim())` of the last axis.
    pub fn write_slice_last(&mut self, start: usize, part: &HostTensor) {
        let c = self.last_dim();
        let len = part.last_dim();
        assert!(start + len <= c, "write_slice_last out of range");
        assert_eq!(self.rows(), part.rows(), "row mismatch");
        for r in 0..self.rows() {
            self.data[r * c + start..r * c + start + len]
                .copy_from_slice(&part.data[r * len..(r + 1) * len]);
        }
    }

    /// Slice `[start, start+count)` of the FIRST axis (row shards).
    pub fn slice_first(&self, start: usize, count: usize) -> HostTensor {
        assert!(!self.shape.is_empty());
        let stride: usize = self.shape[1..].iter().product();
        assert!(start + count <= self.shape[0], "slice_first out of range");
        let mut shape = self.shape.clone();
        shape[0] = count;
        HostTensor::from_vec(
            &shape,
            self.data[start * stride..(start + count) * stride].to_vec(),
        )
    }

    pub fn write_slice_first(&mut self, start: usize, part: &HostTensor) {
        let stride: usize = self.shape[1..].iter().product();
        let count = part.shape[0];
        assert!(start + count <= self.shape[0]);
        self.data[start * stride..(start + count) * stride]
            .copy_from_slice(&part.data);
    }

    pub fn max_abs_diff(&self, other: &HostTensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative allclose (tolerance scaled by magnitude, floor 1.0).
    pub fn allclose(&self, other: &HostTensor, tol: f32) -> bool {
        self.shape == other.shape
            && self.data.iter().zip(&other.data).all(|(a, b)| {
                (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
            })
    }
}

impl IntTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        IntTensor { shape: shape.to_vec(), data: vec![0; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        IntTensor { shape: shape.to_vec(), data }
    }

    /// Uniform ids in [0, n) (synthetic token streams).
    pub fn rand_below(shape: &[usize], n: i32, rng: &mut Rng) -> Self {
        let mut t = Self::zeros(shape);
        rng.fill_uniform_i32(&mut t.data, n);
        t
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_slice_roundtrip() {
        let a = HostTensor::from_vec(&[2, 2], vec![1., 2., 5., 6.]);
        let b = HostTensor::from_vec(&[2, 3], vec![3., 4., 40., 7., 8., 80.]);
        let c = HostTensor::concat_last(&[&a, &b]);
        assert_eq!(c.shape, vec![2, 5]);
        assert_eq!(c.data, vec![1., 2., 3., 4., 40., 5., 6., 7., 8., 80.]);
        assert_eq!(c.slice_last(0, 2), a);
        assert_eq!(c.slice_last(2, 3), b);
    }

    #[test]
    fn write_slice_last_roundtrip() {
        let mut full = HostTensor::zeros(&[2, 4]);
        let part = HostTensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        full.write_slice_last(2, &part);
        assert_eq!(full.slice_last(2, 2), part);
        assert_eq!(full.data[0], 0.0);
    }

    #[test]
    fn first_axis_shards() {
        let t = HostTensor::from_vec(&[4, 2], (0..8).map(|x| x as f32).collect());
        let s = t.slice_first(1, 2);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![2., 3., 4., 5.]);
        let mut t2 = HostTensor::zeros(&[4, 2]);
        t2.write_slice_first(1, &s);
        assert_eq!(t2.slice_first(1, 2), s);
    }

    #[test]
    fn sum_leading_is_bias_grad() {
        let t = HostTensor::from_vec(&[2, 2, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        assert_eq!(t.sum_leading().data, vec![16., 20.]);
    }

    #[test]
    fn broadcast_add() {
        let mut t = HostTensor::zeros(&[2, 3]);
        t.add_row_broadcast(&HostTensor::from_vec(&[3], vec![1., 2., 3.]));
        assert_eq!(t.data, vec![1., 2., 3., 1., 2., 3.]);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = HostTensor::randn(&[8], 0.02, &mut r1);
        let b = HostTensor::randn(&[8], 0.02, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn allclose_tolerates_scale() {
        let a = HostTensor::from_vec(&[2], vec![100.0, 1.0]);
        let b = HostTensor::from_vec(&[2], vec![100.001, 1.0]);
        assert!(a.allclose(&b, 1e-4));
        assert!(!a.allclose(&b, 1e-7));
    }
}
