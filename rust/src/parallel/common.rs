//! Shared engine infrastructure: the per-rank execution context (the view
//! one SPMD participant computes against), the cluster-level facade
//! context, tracked buffers, the generic op-call helper every engine
//! computes through, and batch handling.
//!
//! Design invariants:
//! - (DESIGN.md §4) real and virtual mode run the SAME engine code.
//!   `call_op` charges the memory tracker and the timeline identically in
//!   both; only the presence of data differs.
//! - (SPMD) a [`RankEngine`](super::RankEngine) sees ONLY its own rank's
//!   resources through [`RankCtx`]: its memory tracker, its fabric port,
//!   its executor. Cross-rank data moves exclusively through the port.
//!   Rank 0 is the *modeled* rank: it alone holds the timeline and emits
//!   the once-per-collective trace events (the schedule is symmetric, so
//!   modeling one rank models all).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::cluster::{Cluster, TraceEvent, TraceLog};
use crate::comm::{CollectiveStream, CommPrim, CommStream, RingPort, SchedPolicy};
use crate::config::{ModelCfg, ParallelCfg};
use crate::memory::tracker::{AllocId, MemCategory, MemTracker};
use crate::model::ops::{self, Op};
use crate::perfmodel::{Timeline, Token};
use crate::runtime::fault::{FaultInjector, FaultPhase};
use crate::runtime::{ArgRef, Buf, Exec};
use crate::tensor::{HostTensor, IntTensor};
use crate::util::rng::Rng;

/// A tracker-registered buffer: every transient engine buffer flows
/// through this so peak-memory figures see it.
#[derive(Debug)]
pub struct TBuf {
    pub buf: Buf,
    pub id: AllocId,
    pub worker: usize,
}

impl TBuf {
    pub fn f(&self) -> &HostTensor {
        self.buf.f()
    }
    pub fn f_mut(&mut self) -> &mut HostTensor {
        self.buf.f_mut()
    }
    pub fn is_virtual(&self) -> bool {
        self.buf.is_virtual()
    }
}

/// One training batch (global): token ids + next-token targets, [B, S].
#[derive(Debug, Clone)]
pub struct Batch {
    pub ids: IntTensor,
    pub targets: IntTensor,
}

impl Batch {
    /// Uniform-random synthetic batch (capacity/throughput figures).
    pub fn synth(cfg: &ModelCfg, global_batch: usize, rng: &mut Rng) -> Batch {
        Batch {
            ids: IntTensor::rand_below(&[global_batch, cfg.seq], cfg.vocab as i32, rng),
            targets: IntTensor::rand_below(&[global_batch, cfg.seq], cfg.vocab as i32, rng),
        }
    }

    /// Batch-dimension shard `w` of `n` (rows, contiguous).
    pub fn shard(&self, w: usize, n: usize) -> Batch {
        let b = self.ids.shape[0];
        assert_eq!(b % n, 0, "global batch {b} not divisible by {n}");
        let per = b / n;
        let slice = |t: &IntTensor| {
            let s = t.shape[1];
            IntTensor::from_vec(
                &[per, s],
                t.data[w * per * s..(w + 1) * per * s].to_vec(),
            )
        };
        Batch { ids: slice(&self.ids), targets: slice(&self.targets) }
    }
}

/// The cluster-level facade context: what the trainer, benches and tests
/// read between steps (per-worker trackers, the trace, the timeline).
/// During a step the [`ClusterEngine`](super::ClusterEngine) facade
/// carves this into per-rank [`RankCtx`] views.
pub struct Ctx {
    pub cfg: ModelCfg,
    pub par: ParallelCfg,
    /// Rank 0's executor (ranks 1.. hold their own instances in the
    /// facade — one executor per simulated device, true SPMD).
    pub exec: Exec,
    pub cluster: Cluster,
    /// Present when modeling step time (virtual-mode sweeps). Lent to
    /// rank 0 for the duration of each step — the schedule is symmetric
    /// SPMD, so one modeled rank models all.
    pub timeline: Option<Timeline>,
}

impl Ctx {
    pub fn n(&self) -> usize {
        self.par.workers
    }

    pub fn virtual_mode(&self) -> bool {
        self.exec.is_virtual()
    }
}

/// Everything ONE rank computes against during a step: its own tracker,
/// its own executor, its own fabric port — plus, on rank 0 only, the
/// timeline and the (shared, mutex-guarded) trace log.
pub struct RankCtx<'a> {
    pub rank: usize,
    pub cfg: &'a ModelCfg,
    pub par: &'a ParallelCfg,
    pub exec: &'a mut Exec,
    pub tracker: &'a mut MemTracker,
    pub port: RingPort,
    /// Rank 0 only (symmetric SPMD: one modeled rank).
    pub timeline: Option<&'a mut Timeline>,
    /// Shared trace sink; locked only when tracing is on.
    pub trace_log: &'a Mutex<TraceLog>,
    /// Cached `trace_log.enabled` (skip the lock on the hot path).
    pub trace_on: bool,
    /// True when this rank's comm streams may overlap hops for real
    /// (Thread launcher with async rotation enabled). Under Lockstep this
    /// is always false, so streams degrade to the deterministic
    /// synchronous boundary schedule.
    pub async_comm: bool,
    /// Hop-level scheduling policy for this rank's background collective
    /// engine (identical on every rank; results are policy-invariant).
    pub sched_policy: SchedPolicy,
    /// Size target for gradient bucketing (`None` = one monolithic
    /// bucket, the historical behavior). Identical on every rank.
    pub bucket_bytes: Option<u64>,
    /// Deterministic fault-injection harness (`None` = no plan). Shared
    /// by every rank of the engine; each fault point is a pure comparison
    /// against the plan, so an unmatched plan is a bit-identical no-op.
    pub fault: Option<Arc<FaultInjector>>,
}

impl<'a> RankCtx<'a> {
    pub fn n(&self) -> usize {
        self.par.workers
    }

    pub fn virtual_mode(&self) -> bool {
        self.exec.is_virtual()
    }

    /// Is this the modeled rank (timeline + once-per-collective traces)?
    pub fn lead(&self) -> bool {
        self.rank == 0
    }

    /// This rank's comm stream for an engine path that wants overlap.
    /// `overlapped` is the ENGINE's wish (e.g. RTP out-of-place); the hop
    /// only actually runs in the background when the launcher provides
    /// real concurrency too (`async_comm`).
    pub fn comm_stream(&self, overlapped: bool) -> CommStream {
        CommStream::new(self.port.clone(), overlapped && self.async_comm)
    }

    /// This rank's BACKGROUND COLLECTIVE ENGINE: queued multi-hop
    /// collectives (allgather / reduce-scatter / allreduce) execute on a
    /// dedicated per-rank comm thread over the fabric's background lane
    /// namespace when the launcher provides real concurrency
    /// (`async_comm`), and degrade to deterministic execute-at-join under
    /// Lockstep — bit-identical either way. Engines create one per rank
    /// lazily at the first step (construction-time contexts predate the
    /// launcher decision) and keep it for the rank's lifetime.
    pub fn collectives(&self) -> CollectiveStream {
        CollectiveStream::with_policy_fault(
            self.port.clone(),
            self.async_comm,
            self.sched_policy,
            self.fault.clone(),
        )
    }

    /// An instrumented fault point: dies here iff the engine's
    /// [`FaultPlan`](crate::runtime::fault::FaultPlan) names this rank,
    /// the current step, and `phase`. No-op (and bit-identical) otherwise.
    pub fn fault_point(&self, phase: FaultPhase) {
        if let Some(f) = &self.fault {
            f.fault_point(self.rank, phase);
        }
    }

    /// Gradient-bucket size target in ELEMENTS (`None` = unbucketed).
    pub fn bucket_elems(&self) -> Option<usize> {
        self.bucket_bytes.map(|b| ((b / 4) as usize).max(1))
    }

    /// Allocate a tracked buffer on this rank.
    pub fn alloc(&mut self, cat: MemCategory, buf: Buf) -> Result<TBuf> {
        let bytes = buf.bytes();
        if cat == MemCategory::CommBuf {
            // comm-buffer churn against a near-capacity working set is
            // what thrashes the caching allocator (the paper's FSDP
            // full-batch cliff). The step's WORKING SET (peak so far), not
            // the instantaneous live, is what the allocator cache holds —
            // see Timeline::alloc_event.
            let (peak, live) = (self.tracker.peak(), self.tracker.live());
            if let Some(tl) = self.timeline.as_deref_mut() {
                tl.alloc_event(peak.max(live), bytes);
            }
        }
        let id = self.tracker.alloc(cat, bytes)?;
        Ok(TBuf { buf, id, worker: self.rank })
    }

    pub fn free(&mut self, t: TBuf) {
        debug_assert_eq!(t.worker, self.rank, "freeing another rank's buffer");
        self.tracker.free(t.id);
    }

    /// §3.4.4 buffer recycling: retag a dead comm buffer as activations.
    pub fn recycle(&mut self, t: &TBuf, to: MemCategory) {
        self.tracker.recycle(t.id, to);
    }

    /// The universal op call: charges the timeline (modeled rank), runs
    /// this rank's executor, and registers every output with this rank's
    /// tracker under the caller's categories.
    pub fn call_op(
        &mut self,
        op: Op,
        b: usize,
        p: usize,
        args: &[ArgRef],
        out_cats: &[MemCategory],
    ) -> Result<Vec<TBuf>> {
        if let Some(tl) = self.timeline.as_deref_mut() {
            tl.compute(op.key_name(), &ops::op_cost(op, self.cfg, b, p));
        }
        let outs = self.exec.call(op, self.cfg, b, p, args)?;
        debug_assert_eq!(outs.len(), out_cats.len(), "{op}: out_cats arity");
        outs.into_iter()
            .zip(out_cats)
            .map(|(buf, &cat)| self.alloc(cat, buf))
            .collect()
    }

    /// Trace helper (no-op unless tracing is on). Every rank pushes its
    /// own compute events; collective-level events go through
    /// [`RankCtx::phase`] / [`RankCtx::charge_comm`] (lead rank only).
    pub fn trace(&mut self, e: TraceEvent) {
        if self.trace_on {
            self.trace_log.lock().unwrap().push(e);
        }
    }

    /// Phase marker — lead rank only (one marker per cluster-wide phase).
    pub fn phase(&mut self, name: &str) {
        if self.lead() && self.trace_on {
            self.trace_log.lock().unwrap().phase(name);
        }
    }

    /// Trace the per-hop schedule of one collective (lead rank only:
    /// symmetric SPMD — one event per hop, not per rank).
    fn trace_hops(&mut self, prim: CommPrim, bytes: u64) {
        if !self.lead() || !self.trace_on {
            return;
        }
        let hops = prim.hop_schedule(bytes, self.n());
        let of = hops.len();
        let mut log = self.trace_log.lock().unwrap();
        for (hop, hop_bytes) in hops.into_iter().enumerate() {
            log.push(TraceEvent::Hop {
                prim,
                hop,
                of,
                bytes_per_rank: hop_bytes as u64,
            });
        }
    }

    /// Charge one BLOCKING ring collective: per-hop spans on the modeled
    /// rank's timeline plus per-hop trace events. Every rank calls this
    /// at its own collective call site; only the lead rank records.
    pub fn charge_comm(&mut self, label: &str, prim: CommPrim, bytes: u64) {
        self.trace_hops(prim, bytes);
        if let Some(tl) = self.timeline.as_deref_mut() {
            tl.comm_blocking(label, prim, bytes);
        }
    }

    /// Charge an ASYNC ring collective issued after the compute enqueued
    /// so far; returns the completion token on the modeled rank.
    pub fn charge_comm_async(
        &mut self,
        label: &str,
        prim: CommPrim,
        bytes: u64,
    ) -> Option<Token> {
        self.trace_hops(prim, bytes);
        self.timeline
            .as_deref_mut()
            .map(|tl| tl.comm_async(label, prim, bytes))
    }

    /// Charge an ASYNC ring collective whose payload is already in hand
    /// (starts as soon as the comm stream frees — §3.4.3 eager overlap).
    pub fn charge_comm_async_eager(
        &mut self,
        label: &str,
        prim: CommPrim,
        bytes: u64,
    ) -> Option<Token> {
        self.trace_hops(prim, bytes);
        self.timeline
            .as_deref_mut()
            .map(|tl| tl.comm_async_eager(label, prim, bytes))
    }

    /// Block the modeled compute stream on an async collective's token.
    pub fn charge_wait(&mut self, tok: Option<Token>) {
        if let (Some(tl), Some(t)) = (self.timeline.as_deref_mut(), tok) {
            tl.wait(t);
        }
    }

    // -- real-mode host glue (no-ops in virtual mode) --------------------

    /// Merged-output bias add (the "+bo / +b2 applied once" convention).
    pub fn add_bias(&mut self, x: &mut TBuf, bias: Option<&HostTensor>) {
        if let (Buf::Real(t), Some(b)) = (&mut x.buf, bias) {
            t.add_row_broadcast(b);
        }
    }

    /// Accumulate `part` into `acc` (sum-merge).
    pub fn accumulate(&mut self, acc: &mut TBuf, part: &TBuf) {
        if let (Buf::Real(a), Buf::Real(p)) = (&mut acc.buf, &part.buf) {
            a.add_assign(p);
        }
    }

    /// Residual add: x = x + part, reusing x's buffer.
    pub fn residual(&mut self, x: &mut TBuf, part: &TBuf) {
        self.accumulate(x, part);
    }

    /// Write a column slice (concat-merge assembly).
    pub fn write_col_slice(&mut self, full: &mut TBuf, start: usize, part: &TBuf) {
        if let (Buf::Real(f), Buf::Real(p)) = (&mut full.buf, &part.buf) {
            f.write_slice_last(start, p);
        }
    }

    /// Read a column slice as a new tracked buffer (concat-merge backward).
    pub fn col_slice(
        &mut self,
        src: &TBuf,
        start: usize,
        len: usize,
        cat: MemCategory,
    ) -> Result<TBuf> {
        let buf = match &src.buf {
            Buf::Real(t) => Buf::Real(t.slice_last(start, len)),
            _ => {
                let mut shape = src.buf.shape().to_vec();
                *shape.last_mut().unwrap() = len;
                Buf::Virt(shape)
            }
        };
        self.alloc(cat, buf)
    }

    /// Mean loss from a scalar xent output (0.0 in virtual mode).
    pub fn loss_of(&self, t: &TBuf) -> f32 {
        match &t.buf {
            Buf::Real(h) => h.data[0],
            _ => 0.0,
        }
    }
}

/// Ring-allgather one rank's shard tensor through its port: every rank
/// ends with all N shards in rank order, reshaped to the (common) shard
/// shape. The gather/checkpoint path of the sharded engines — every rank
/// must call it inside a fabric round.
pub fn allgather_tensor(port: &RingPort, t: &HostTensor) -> Vec<HostTensor> {
    crate::comm::allgather_parts(port, &t.data)
        .into_iter()
        .map(|d| HostTensor::from_vec(&t.shape, d))
        .collect()
}

/// The replicated (non-sharded) parameters TP/RTP keep per worker: LN
/// gains/biases, merged-output biases, the MoE router. Tiny vs W, so the
/// paper's tables ignore them; we still track their bytes exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct RepLayer {
    pub ln1_g: HostTensor,
    pub ln1_b: HostTensor,
    pub bo: HostTensor,
    pub ln2_g: HostTensor,
    pub ln2_b: HostTensor,
    pub b2: HostTensor,
    pub wr: Option<HostTensor>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct RepParams {
    pub layers: Vec<RepLayer>,
    pub lnf_g: HostTensor,
    pub lnf_b: HostTensor,
}

impl RepParams {
    pub fn from_full(full: &crate::model::ModelParams) -> RepParams {
        RepParams {
            layers: full
                .layers
                .iter()
                .map(|l| RepLayer {
                    ln1_g: l.ln1_g.clone(),
                    ln1_b: l.ln1_b.clone(),
                    bo: l.bo.clone(),
                    ln2_g: l.ln2_g.clone(),
                    ln2_b: l.ln2_b.clone(),
                    b2: match &l.mlp {
                        crate::model::MlpParams::Dense { b2, .. } => b2.clone(),
                        crate::model::MlpParams::Moe { b2, .. } => b2.clone(),
                    },
                    wr: match &l.mlp {
                        crate::model::MlpParams::Moe { wr, .. } => Some(wr.clone()),
                        _ => None,
                    },
                })
                .collect(),
            lnf_g: full.lnf_g.clone(),
            lnf_b: full.lnf_b.clone(),
        }
    }

    pub fn zeros_like(&self) -> RepParams {
        let mut z = self.clone();
        z.visit_mut(&mut |t| t.data.fill(0.0));
        z
    }

    pub fn visit_mut(&mut self, f: &mut dyn FnMut(&mut HostTensor)) {
        for l in &mut self.layers {
            f(&mut l.ln1_g);
            f(&mut l.ln1_b);
            f(&mut l.bo);
            f(&mut l.ln2_g);
            f(&mut l.ln2_b);
            f(&mut l.b2);
            if let Some(wr) = &mut l.wr {
                f(wr);
            }
        }
        f(&mut self.lnf_g);
        f(&mut self.lnf_b);
    }

    pub fn visit(&self, f: &mut dyn FnMut(&HostTensor)) {
        for l in &self.layers {
            f(&l.ln1_g);
            f(&l.ln1_b);
            f(&l.bo);
            f(&l.ln2_g);
            f(&l.ln2_b);
            f(&l.b2);
            if let Some(wr) = &l.wr {
                f(wr);
            }
        }
        f(&self.lnf_g);
        f(&self.lnf_b);
    }

    pub fn numel(&self) -> usize {
        let mut n = 0;
        self.visit(&mut |t| n += t.numel());
        n
    }

    pub fn bytes(&self) -> u64 {
        (self.numel() * 4) as u64
    }

    /// Flatten to one message (for the replicated-grad allreduce).
    pub fn pack(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.pack_into(&mut out);
        out
    }

    /// [`RepParams::pack`] into a caller-owned scratch buffer, so the
    /// per-step replicated-grad allreduce reuses one allocation for the
    /// life of the rank engine.
    pub fn pack_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.numel());
        self.visit(&mut |t| out.extend_from_slice(&t.data));
    }

    pub fn unpack(&mut self, flat: &[f32]) {
        let mut off = 0;
        self.visit_mut(&mut |t| {
            let len = t.data.len();
            t.data.copy_from_slice(&flat[off..off + len]);
            off += len;
        });
        assert_eq!(off, flat.len(), "RepParams unpack length mismatch");
    }
}

/// Replicated-parameter element count straight from the config (virtual
/// mode has no tensors to count).
pub fn replicated_elems(cfg: &ModelCfg) -> usize {
    // per layer: ln1 g+b, bo, ln2 g+b, b2 = 6H (+ router H*E for MoE)
    let per_layer = 6 * cfg.hidden
        + if cfg.is_moe() { cfg.hidden * cfg.experts } else { 0 };
    cfg.layers * per_layer + 2 * cfg.hidden
}

/// Top-1 gates from router probs: gates[e][b,s] = prob_e if argmax == e
/// else 0. Host-side (routing is control flow, not a kernel).
pub fn top1_gates(probs: &HostTensor, experts: usize) -> Vec<HostTensor> {
    let e = probs.last_dim();
    assert_eq!(e, experts);
    let rows = probs.rows();
    let lead = &probs.shape[..probs.shape.len() - 1];
    let mut gates: Vec<HostTensor> =
        (0..experts).map(|_| HostTensor::zeros(lead)).collect();
    for r in 0..rows {
        let row = &probs.data[r * e..(r + 1) * e];
        let (best, &p) = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        gates[best].data[r] = p;
    }
    gates
}

/// Scatter per-expert dgates back into a dprobs tensor (inverse of
/// `top1_gates` for the backward pass): dprobs[..., e] = dgates_e where
/// expert e was selected, 0 elsewhere.
pub fn scatter_dgates(
    dgates: &[(usize, HostTensor)],
    probs: &HostTensor,
) -> HostTensor {
    let e = probs.last_dim();
    let rows = probs.rows();
    let mut dprobs = HostTensor::zeros(&probs.shape);
    // recompute the argmax routing
    for r in 0..rows {
        let row = &probs.data[r * e..(r + 1) * e];
        let best = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        for (ei, dg) in dgates {
            if *ei == best {
                dprobs.data[r * e + best] = dg.data[r];
            }
        }
    }
    dprobs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Strategy};

    /// One-rank harness: owned resources + a RankCtx view over them.
    struct RankHarness {
        cfg: ModelCfg,
        par: ParallelCfg,
        exec: Exec,
        tracker: MemTracker,
        fabric: crate::comm::RingFabric,
        timeline: Option<Timeline>,
        trace: Mutex<TraceLog>,
    }

    impl RankHarness {
        fn new(n: usize) -> RankHarness {
            RankHarness {
                cfg: presets::get("tiny").unwrap(),
                par: ParallelCfg {
                    strategy: Strategy::RtpInplace,
                    workers: n,
                    global_batch: 4,
                },
                exec: Exec::Virtual,
                tracker: MemTracker::new(0, None),
                fabric: crate::comm::RingFabric::new(n),
                timeline: None,
                trace: Mutex::new(TraceLog::default()),
            }
        }

        fn ctx(&mut self) -> RankCtx<'_> {
            let trace_on = self.trace.lock().unwrap().enabled;
            RankCtx {
                rank: 0,
                cfg: &self.cfg,
                par: &self.par,
                exec: &mut self.exec,
                tracker: &mut self.tracker,
                port: self.fabric.port(0),
                timeline: self.timeline.as_mut(),
                trace_log: &self.trace,
                trace_on,
                async_comm: false,
                sched_policy: SchedPolicy::Fifo,
                bucket_bytes: None,
                fault: None,
            }
        }
    }

    #[test]
    fn batch_shard_partitions_rows() {
        let cfg = presets::get("tiny").unwrap();
        let mut rng = Rng::new(1);
        let b = Batch::synth(&cfg, 4, &mut rng);
        let s0 = b.shard(0, 2);
        let s1 = b.shard(1, 2);
        assert_eq!(s0.ids.shape, vec![2, cfg.seq]);
        assert_eq!(
            [s0.ids.data.clone(), s1.ids.data.clone()].concat(),
            b.ids.data
        );
    }

    #[test]
    fn call_op_tracks_outputs() {
        let mut h = RankHarness::new(2);
        let mut c = h.ctx();
        let outs = c
            .call_op(Op::LnFwd, 2, 1, &[], &[MemCategory::Activations])
            .unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(c.tracker.live(), outs[0].buf.bytes());
        for o in outs {
            c.free(o);
        }
        assert_eq!(c.tracker.live(), 0);
    }

    #[test]
    fn charge_comm_traces_and_times_per_hop() {
        let mut h = RankHarness::new(4);
        h.trace = Mutex::new(TraceLog::enabled());
        h.timeline = Some(Timeline::new(crate::perfmodel::a100_nvlink(), 4));
        let mut c = h.ctx();
        c.charge_comm("ar", crate::comm::CommPrim::AllReduce, 4 << 20);
        // 2(N-1) = 6 hop events traced and 6 hops on the timeline
        assert_eq!(c.timeline.as_ref().unwrap().hop_count, 6);
        let tok =
            c.charge_comm_async("rs", crate::comm::CommPrim::ReduceScatter, 4 << 20);
        assert!(tok.is_some());
        c.charge_wait(tok);
        assert_eq!(h.trace.lock().unwrap().fabric_hops(), 9);
    }

    #[test]
    fn non_lead_ranks_do_not_trace_hops() {
        let mut h = RankHarness::new(4);
        h.trace = Mutex::new(TraceLog::enabled());
        let trace_on = true;
        let mut c = RankCtx {
            rank: 2,
            cfg: &h.cfg,
            par: &h.par,
            exec: &mut h.exec,
            tracker: &mut h.tracker,
            port: h.fabric.port(2),
            timeline: None,
            trace_log: &h.trace,
            trace_on,
            async_comm: false,
            sched_policy: SchedPolicy::Fifo,
            bucket_bytes: None,
            fault: None,
        };
        c.charge_comm("ar", crate::comm::CommPrim::AllReduce, 4 << 20);
        c.phase("forward");
        assert_eq!(h.trace.lock().unwrap().events.len(), 0);
    }

    #[test]
    fn replicated_elems_matches_packed() {
        let cfg = presets::get("tiny-moe").unwrap();
        let full = crate::model::ModelParams::init(&cfg, &mut Rng::new(2));
        let rep = RepParams::from_full(&full);
        assert_eq!(rep.numel(), replicated_elems(&cfg));
        let flat = rep.pack();
        assert_eq!(flat.len(), rep.numel());
        let mut rep2 = rep.zeros_like();
        rep2.unpack(&flat);
        assert_eq!(rep, rep2);
    }

    #[test]
    fn top1_gates_select_max_prob() {
        // 2 tokens, 3 experts
        let probs = HostTensor::from_vec(&[1, 2, 3], vec![0.2, 0.5, 0.3, 0.7, 0.1, 0.2]);
        let gates = top1_gates(&probs, 3);
        assert_eq!(gates[1].data, vec![0.5, 0.0]);
        assert_eq!(gates[0].data, vec![0.0, 0.7]);
        assert_eq!(gates[2].data, vec![0.0, 0.0]);
        // each token routed exactly once
        let total: f32 = gates
            .iter()
            .map(|g| g.data.iter().filter(|&&v| v > 0.0).count() as f32)
            .sum();
        assert_eq!(total, 2.0);
    }

    #[test]
    fn scatter_dgates_inverts_routing() {
        let probs = HostTensor::from_vec(&[1, 2, 2], vec![0.9, 0.1, 0.2, 0.8]);
        let dg0 = HostTensor::from_vec(&[1, 2], vec![5.0, 0.0]);
        let dg1 = HostTensor::from_vec(&[1, 2], vec![0.0, 7.0]);
        let dprobs = scatter_dgates(&[(0, dg0), (1, dg1)], &probs);
        assert_eq!(dprobs.data, vec![5.0, 0.0, 0.0, 7.0]);
    }
}
