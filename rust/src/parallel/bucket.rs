//! Size-targeted gradient bucketing: split one flat gradient vector into
//! contiguous buckets of roughly `target` bytes and allreduce each bucket
//! as its OWN in-flight collective on the rank's
//! [`CollectiveStream`](crate::comm::CollectiveStream).
//!
//! The point is scheduling, not arithmetic: a monolithic allreduce gives
//! the background comm thread exactly one collective to chew through (a
//! convoy), while several bucket allreduces issued back-to-back give the
//! hop-level scheduler a SET of in-flight collectives whose hops it can
//! interleave — latency-critical work (an FSDP prefetch allgather, an
//! early bucket a joiner is already waiting on) no longer queues behind a
//! giant tail bucket. DDP and RTP drive this through
//! `RankCtx::bucket_elems` (`EngineOpts::bucket_bytes` /
//! `RTP_BUCKET_BYTES`).
//!
//! Numerics: the chunk boundaries of the ring allreduce depend on the
//! buffer length, so a bucketed reduction sums floats in a different
//! order than a monolithic one — bit-identical across launchers and
//! scheduling policies *given the same bucket size*, but NOT between
//! bucketed and monolithic runs. Hence the knob defaults to off.
//!
//! Allocation: per-bucket payload buffers and the handle scratch persist
//! on the owning rank engine and are recycled through the stream's
//! caller-owned-buffer contract — zero steady-state heap allocations,
//! same as the monolithic path.

use std::ops::Range;

use crate::comm::{CollectiveStream, CollHandle};

/// Contiguous bucket bounds: `total` elements split into buckets of at
/// most `target_elems` elements (every bucket but the last is exactly
/// `target_elems`). Deterministic in its inputs — all ranks compute the
/// same split. Empty input yields no buckets.
pub fn bucket_ranges(total: usize, target_elems: usize) -> Vec<Range<usize>> {
    assert!(target_elems > 0, "bucket target must be positive");
    (0..total.div_ceil(target_elems))
        .map(|k| k * target_elems..((k + 1) * target_elems).min(total))
        .collect()
}

/// Persistent scratch + the issue-all-then-join-all discipline for a
/// bucketed allreduce. One `GradBuckets` lives on each rank engine next
/// to its flat-pack scratch.
#[derive(Default)]
pub struct GradBuckets {
    /// Per-bucket payload buffers, recycled across steps.
    bufs: Vec<Vec<f32>>,
    /// Issued-handle scratch, drained every call.
    handles: Vec<CollHandle>,
}

impl GradBuckets {
    pub fn new() -> GradBuckets {
        GradBuckets::default()
    }

    /// Allreduce-sum `flat` in place through `stream`, split into
    /// contiguous buckets of at most `target_elems` elements. EVERY
    /// bucket is issued before the first is joined, so the whole set is
    /// in flight at once — that is the multi-collective workload the hop
    /// scheduler interleaves. Returns the number of buckets. All ranks
    /// must call with identical lengths and targets (symmetric SPMD).
    pub fn allreduce_flat(
        &mut self,
        stream: &CollectiveStream,
        flat: &mut [f32],
        target_elems: usize,
    ) -> usize {
        assert!(target_elems > 0, "bucket target must be positive");
        let nb = flat.len().div_ceil(target_elems);
        while self.bufs.len() < nb {
            self.bufs.push(Vec::new());
        }
        debug_assert!(self.handles.is_empty(), "handle scratch not drained");
        for k in 0..nb {
            let r = k * target_elems..((k + 1) * target_elems).min(flat.len());
            let mut b = std::mem::take(&mut self.bufs[k]);
            b.clear();
            b.extend_from_slice(&flat[r]);
            self.handles.push(stream.issue_allreduce(b));
        }
        for (k, h) in self.handles.drain(..).enumerate() {
            let r = k * target_elems..((k + 1) * target_elems).min(flat.len());
            let b = stream.join(h);
            flat[r].copy_from_slice(&b);
            self.bufs[k] = b;
        }
        nb
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::{LaunchPolicy, RingFabric};
    use crate::comm::SchedPolicy;

    #[test]
    fn bucket_ranges_cover_contiguously() {
        for (total, target) in [(0usize, 3usize), (1, 3), (3, 3), (10, 3), (10, 100)] {
            let rs = bucket_ranges(total, target);
            assert_eq!(rs.len(), total.div_ceil(target), "{total}/{target}");
            let mut at = 0;
            for r in &rs {
                assert_eq!(r.start, at, "{total}/{target}");
                assert!(r.end - r.start <= target, "{total}/{target}");
                assert!(r.end > r.start, "{total}/{target}");
                at = r.end;
            }
            assert_eq!(at, total, "{total}/{target}");
        }
    }

    /// Bucketed allreduce computes the same sums as the direct formula
    /// (integer payloads: exact under any summation order), under both
    /// launchers and every policy, with all buckets in flight at once.
    #[test]
    fn bucketed_allreduce_sums_across_ranks() {
        let (len, target) = (10usize, 3usize);
        for n in [1usize, 2, 4] {
            for (policy, bg, sched) in [
                (LaunchPolicy::Lockstep, false, SchedPolicy::Fifo),
                (LaunchPolicy::Threaded, true, SchedPolicy::Fifo),
                (LaunchPolicy::Threaded, true, SchedPolicy::RoundRobin),
                (LaunchPolicy::Threaded, true, SchedPolicy::Priority),
            ] {
                let fab = RingFabric::new(n);
                let tasks: Vec<Box<dyn FnOnce() -> Vec<f32> + Send>> = (0..n)
                    .map(|r| {
                        let stream =
                            CollectiveStream::with_policy(fab.port(r), bg, sched);
                        Box::new(move || {
                            let mut flat: Vec<f32> =
                                (0..len).map(|i| (r * 100 + i) as f32).collect();
                            let mut gb = GradBuckets::new();
                            let nb = gb.allreduce_flat(&stream, &mut flat, target);
                            assert_eq!(nb, len.div_ceil(target));
                            // second step reuses the warmed scratch
                            let nb2 = gb.allreduce_flat(&stream, &mut flat, target);
                            assert_eq!(nb2, nb);
                            flat
                        }) as Box<dyn FnOnce() -> Vec<f32> + Send>
                    })
                    .collect();
                let out = fab.run_round(policy, tasks);
                assert_eq!(fab.in_flight(), 0);
                for flat in out {
                    for (i, v) in flat.iter().enumerate() {
                        // two allreduce-sum passes: n * (n * sum_r(r*100+i))
                        let once: f32 =
                            (0..n).map(|r| (r * 100 + i) as f32).sum();
                        assert_eq!(*v, once * n as f32, "n={n} i={i}");
                    }
                }
            }
        }
    }
}
