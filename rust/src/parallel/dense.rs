//! The dense (p = 1, full-weight) forward+backward walk shared by the
//! `single`, `ddp` and `fsdp` engines.
//!
//! The walk is written once against the `DenseHooks` trait: `single`/`ddp`
//! hand out their resident replica and accumulate grads locally; `fsdp`
//! allgathers each unit's FlatParameter on `unit_begin`, frees it on
//! `unit_end`, and reduce-scatters unit grads. The compute sequence —
//! and therefore every tracker allocation and timeline charge — is
//! identical across the three, which is exactly the comparison the
//! paper's memory figures make.

use anyhow::Result;

use crate::memory::tracker::MemCategory;
use crate::model::ops::Op;
use crate::model::{MlpParams, ModelParams};
use crate::runtime::fault::FaultPhase;
use crate::runtime::{arg_of, Buf};
use crate::tensor::HostTensor;

use super::common::{scatter_dgates, top1_gates, Batch, RankCtx, TBuf};

/// FSDP-style unit granularity over the dense model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    /// wte + wpe
    Emb,
    /// One transformer layer (ln1 + attn + ln2 + mlp/moe).
    Layer(usize),
    /// lnf + LM head.
    Final,
}

impl Unit {
    pub fn all(layers: usize) -> Vec<Unit> {
        let mut v = vec![Unit::Emb];
        v.extend((0..layers).map(Unit::Layer));
        v.push(Unit::Final);
        v
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Fwd,
    Bwd,
}

/// A weight-grad destination slot (parameter identity).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Slot {
    pub layer: Option<usize>,
    pub expert: Option<usize>,
    pub name: &'static str,
}

impl Slot {
    pub fn global(name: &'static str) -> Slot {
        Slot { layer: None, expert: None, name }
    }
    pub fn layer(l: usize, name: &'static str) -> Slot {
        Slot { layer: Some(l), expert: None, name }
    }
    pub fn expert(l: usize, e: usize, name: &'static str) -> Slot {
        Slot { layer: Some(l), expert: Some(e), name }
    }

    /// The unit a slot belongs to (FSDP reduce-scatter granularity).
    pub fn unit(&self) -> Unit {
        match self.layer {
            Some(l) => Unit::Layer(l),
            None => match self.name {
                "wte" | "wpe" => Unit::Emb,
                _ => Unit::Final,
            },
        }
    }
}

/// What the dense walk needs from one RANK's engine. The walk is fully
/// rank-local: hooks see only this rank's context, and any cross-rank
/// traffic (FSDP's unit allgather / reduce-scatter) goes through the
/// rank's own fabric port inside the hook.
pub trait DenseHooks {
    /// Make `unit`'s full weights resident on this rank (FSDP: this
    /// rank's side of the unit ring allgather).
    fn unit_begin(&mut self, ctx: &mut RankCtx, unit: Unit, phase: Phase) -> Result<()>;
    /// Done with `unit` in this phase (FSDP: free + in Bwd this rank's
    /// side of the unit reduce-scatter).
    fn unit_end(&mut self, ctx: &mut RankCtx, unit: Unit, phase: Phase) -> Result<()>;
    /// The currently-resident full params (None in virtual mode — the
    /// walk then passes virtual args).
    fn params(&self) -> Option<&ModelParams>;
    /// Consume one weight-grad buffer for `slot` (accumulate + free).
    fn grad(&mut self, ctx: &mut RankCtx, slot: Slot, src: TBuf) -> Result<()>;

    /// Charged before AND after each MoE expert block: the token
    /// all-to-all an expert-parallel DP/FSDP system pays (paper §4 "MOE
    /// Block"). Default: nothing (single device has no exchange).
    fn moe_exchange(&mut self, _ctx: &mut RankCtx, _bytes: u64) -> Result<()> {
        Ok(())
    }
}

/// Per-layer saved activations (recompute-from-inputs policy: only unit
/// INPUTS are stashed, matching the Table-1 activation model).
struct SavedLayer {
    x_in: TBuf,
    a: TBuf,
    x_mid: TBuf,
    m: TBuf,
    /// MoE: router probs + per-expert gates (needed to rebuild routing).
    probs: Option<TBuf>,
    gates: Vec<TBuf>,
}

/// Sum-over-leading-axes bias gradient as a tracked buffer.
fn bias_grad(ctx: &mut RankCtx, dy: &TBuf, dim: usize) -> Result<TBuf> {
    let buf = match &dy.buf {
        Buf::Real(t) => Buf::Real(t.sum_leading()),
        _ => Buf::Virt(vec![dim]),
    };
    ctx.alloc(MemCategory::Grads, buf)
}

/// One full forward+backward on this rank over its batch shard.
/// Returns this rank's mean loss.
pub fn dense_step(
    ctx: &mut RankCtx,
    hooks: &mut dyn DenseHooks,
    batch: &Batch,
) -> Result<f32> {
    let cfg = ctx.cfg.clone();
    let b = batch.ids.shape[0];
    let h = cfg.hidden;
    let virt = ctx.virtual_mode();
    let acts = MemCategory::Activations;

    let ids = ctx.alloc(
        acts,
        if virt { Buf::Virt(vec![b, cfg.seq]) } else { Buf::Ids(batch.ids.clone()) },
    )?;
    let targets = ctx.alloc(
        acts,
        if virt { Buf::Virt(vec![b, cfg.seq]) } else { Buf::Ids(batch.targets.clone()) },
    )?;

    // ---------------- forward ----------------
    ctx.fault_point(FaultPhase::Forward);
    hooks.unit_begin(ctx, Unit::Emb, Phase::Fwd)?;
    let mut x = {
        let p = hooks.params();
        let (wte, wpe) = (p.map(|p| &p.wte), p.map(|p| &p.wpe));
        let mut outs = ctx.call_op(
            Op::EmbFwd,
            b,
            1,
            &[ids.buf.arg(), arg_of(wte), arg_of(wpe)],
            &[acts],
        )?;
        outs.pop().unwrap()
    };
    hooks.unit_end(ctx, Unit::Emb, Phase::Fwd)?;

    let mut saved: Vec<SavedLayer> = Vec::with_capacity(cfg.layers);
    for l in 0..cfg.layers {
        hooks.unit_begin(ctx, Unit::Layer(l), Phase::Fwd)?;
        // ln1 -> attention (+bo) -> residual
        let a = {
            let lp = hooks.params().map(|p| &p.layers[l]);
            let mut outs = ctx.call_op(
                Op::LnFwd,
                b,
                1,
                &[x.buf.arg(), arg_of(lp.map(|l| &l.ln1_g)), arg_of(lp.map(|l| &l.ln1_b))],
                &[acts],
            )?;
            outs.pop().unwrap()
        };
        let mut part = {
            let lp = hooks.params().map(|p| &p.layers[l]);
            let mut outs = ctx.call_op(
                Op::AttnFwd,
                b,
                1,
                &[
                    a.buf.arg(),
                    arg_of(lp.map(|l| &l.wqkv)),
                    arg_of(lp.map(|l| &l.bqkv)),
                    arg_of(lp.map(|l| &l.wo)),
                ],
                &[acts],
            )?;
            outs.pop().unwrap()
        };
        let bo = hooks.params().map(|p| p.layers[l].bo.clone());
        ctx.add_bias(&mut part, bo.as_ref());
        ctx.residual(&mut part, &x);
        let x_mid = part; // new residual stream
        // ln2 -> mlp/moe (+b2) -> residual
        let m = {
            let lp = hooks.params().map(|p| &p.layers[l]);
            let mut outs = ctx.call_op(
                Op::LnFwd,
                b,
                1,
                &[
                    x_mid.buf.arg(),
                    arg_of(lp.map(|l| &l.ln2_g)),
                    arg_of(lp.map(|l| &l.ln2_b)),
                ],
                &[acts],
            )?;
            outs.pop().unwrap()
        };

        let is_moe = cfg.is_moe();
        let (mut part2, probs, gates) = if !is_moe {
            let lp = hooks.params().map(|p| &p.layers[l]);
            let dense = lp.map(|l| match &l.mlp {
                MlpParams::Dense { w1, b1, w2, .. } => (w1, b1, w2),
                _ => unreachable!("dense cfg with moe params"),
            });
            let mut outs = ctx.call_op(
                Op::MlpFwd,
                b,
                1,
                &[
                    m.buf.arg(),
                    arg_of(dense.map(|d| d.0)),
                    arg_of(dense.map(|d| d.1)),
                    arg_of(dense.map(|d| d.2)),
                ],
                &[acts],
            )?;
            (outs.pop().unwrap(), None, Vec::new())
        } else {
            // router -> top-1 gates -> every expert (dense-masked)
            let probs = {
                let lp = hooks.params().map(|p| &p.layers[l]);
                let wr = lp.map(|l| match &l.mlp {
                    MlpParams::Moe { wr, .. } => wr,
                    _ => unreachable!(),
                });
                let mut outs = ctx.call_op(
                    Op::RouterFwd,
                    b,
                    1,
                    &[m.buf.arg(), arg_of(wr)],
                    &[acts],
                )?;
                outs.pop().unwrap()
            };
            let a2a = (b * cfg.seq * h * 4) as u64;
            hooks.moe_exchange(ctx, a2a)?;
            let gate_tensors: Vec<Buf> = if virt {
                (0..cfg.experts).map(|_| Buf::Virt(vec![b, cfg.seq])).collect()
            } else {
                top1_gates(probs.f(), cfg.experts).into_iter().map(Buf::Real).collect()
            };
            let mut gates = Vec::with_capacity(cfg.experts);
            for g in gate_tensors {
                gates.push(ctx.alloc(acts, g)?);
            }
            let mut acc: Option<TBuf> = None;
            for e in 0..cfg.experts {
                let part = {
                    let lp = hooks.params().map(|p| &p.layers[l]);
                    let ex = lp.map(|l| match &l.mlp {
                        MlpParams::Moe { experts, .. } => &experts[e],
                        _ => unreachable!(),
                    });
                    let mut outs = ctx.call_op(
                        Op::MoeFwd,
                        b,
                        1,
                        &[
                            m.buf.arg(),
                            gates[e].buf.arg(),
                            arg_of(ex.map(|x| &x.w1)),
                            arg_of(ex.map(|x| &x.b1)),
                            arg_of(ex.map(|x| &x.w2)),
                        ],
                        &[acts],
                    )?;
                    outs.pop().unwrap()
                };
                match &mut acc {
                    None => acc = Some(part),
                    Some(a) => {
                        ctx.accumulate(a, &part);
                        ctx.free(part);
                    }
                }
            }
            hooks.moe_exchange(ctx, (b * cfg.seq * h * 4) as u64)?;
            (acc.unwrap(), Some(probs), gates)
        };
        let b2 = hooks.params().map(|p| match &p.layers[l].mlp {
            MlpParams::Dense { b2, .. } => b2.clone(),
            MlpParams::Moe { b2, .. } => b2.clone(),
        });
        ctx.add_bias(&mut part2, b2.as_ref());
        ctx.residual(&mut part2, &x_mid);
        hooks.unit_end(ctx, Unit::Layer(l), Phase::Fwd)?;

        saved.push(SavedLayer { x_in: x, a, x_mid, m, probs, gates });
        x = part2;
    }

    // final LN + LM head + loss
    hooks.unit_begin(ctx, Unit::Final, Phase::Fwd)?;
    let xf = {
        let p = hooks.params();
        let mut outs = ctx.call_op(
            Op::LnFwd,
            b,
            1,
            &[x.buf.arg(), arg_of(p.map(|p| &p.lnf_g)), arg_of(p.map(|p| &p.lnf_b))],
            &[acts],
        )?;
        outs.pop().unwrap()
    };
    let logits = {
        let p = hooks.params();
        let mut outs = ctx.call_op(
            Op::LmheadFwd,
            b,
            1,
            &[xf.buf.arg(), arg_of(p.map(|p| &p.wlm))],
            &[acts],
        )?;
        outs.pop().unwrap()
    };
    let mut xent = ctx.call_op(
        Op::Xent,
        b,
        1,
        &[logits.buf.arg(), targets.buf.arg()],
        &[acts, acts],
    )?;
    let dlogits = xent.pop().unwrap();
    let loss_buf = xent.pop().unwrap();
    let loss = ctx.loss_of(&loss_buf);
    ctx.free(loss_buf);
    ctx.free(logits);
    ctx.free(targets);

    // ---------------- backward ----------------
    // The Final unit stayed resident through the loss (its forward
    // unit_end is deliberately absent); unit_begin(Bwd) is what arms the
    // gradient staging (FSDP) and the backward prefetch chain.
    ctx.fault_point(FaultPhase::Backward);
    hooks.unit_begin(ctx, Unit::Final, Phase::Bwd)?;
    let (mut dx, dwlm) = {
        let p = hooks.params();
        let mut outs = ctx.call_op(
            Op::LmheadBwd,
            b,
            1,
            &[xf.buf.arg(), arg_of(p.map(|p| &p.wlm)), dlogits.buf.arg()],
            &[acts, MemCategory::Grads],
        )?;
        let dwlm = outs.pop().unwrap();
        (outs.pop().unwrap(), dwlm)
    };
    hooks.grad(ctx, Slot::global("wlm"), dwlm)?;
    ctx.free(dlogits);

    {
        // grad through lnf: consume xf, x (the lnf input)
        let p = hooks.params();
        let mut outs = ctx.call_op(
            Op::LnBwd,
            b,
            1,
            &[
                x.buf.arg(),
                arg_of(p.map(|p| &p.lnf_g)),
                dx.buf.arg(),
            ],
            &[acts, MemCategory::Grads, MemCategory::Grads],
        )?;
        let db = outs.pop().unwrap();
        let dg = outs.pop().unwrap();
        let new_dx = outs.pop().unwrap();
        hooks.grad(ctx, Slot::global("lnf_b"), db)?;
        hooks.grad(ctx, Slot::global("lnf_g"), dg)?;
        ctx.free(dx);
        dx = new_dx;
    }
    ctx.free(xf);
    ctx.free(x);
    hooks.unit_end(ctx, Unit::Final, Phase::Bwd)?;

    // layers in reverse
    for l in (0..cfg.layers).rev() {
        hooks.unit_begin(ctx, Unit::Layer(l), Phase::Bwd)?;
        let SavedLayer { x_in, a, x_mid, m, probs, gates } = saved.pop().unwrap();

        // dx = grad wrt layer output (x_mid + mlp_part + b2)
        let db2 = bias_grad(ctx, &dx, h)?;
        hooks.grad(ctx, Slot::layer(l, "b2"), db2)?;

        let is_moe = cfg.is_moe();
        let dm_total = if !is_moe {
            let lp = hooks.params().map(|p| &p.layers[l]);
            let dense = lp.map(|lr| match &lr.mlp {
                MlpParams::Dense { w1, b1, w2, .. } => (w1, b1, w2),
                _ => unreachable!(),
            });
            let mut outs = ctx.call_op(
                Op::MlpBwd,
                b,
                1,
                &[
                    m.buf.arg(),
                    arg_of(dense.map(|d| d.0)),
                    arg_of(dense.map(|d| d.1)),
                    arg_of(dense.map(|d| d.2)),
                    dx.buf.arg(),
                ],
                &[acts, MemCategory::Grads, MemCategory::Grads, MemCategory::Grads],
            )?;
            let dw2 = outs.pop().unwrap();
            let db1 = outs.pop().unwrap();
            let dw1 = outs.pop().unwrap();
            let dm = outs.pop().unwrap();
            hooks.grad(ctx, Slot::layer(l, "mlp.w2"), dw2)?;
            hooks.grad(ctx, Slot::layer(l, "mlp.b1"), db1)?;
            hooks.grad(ctx, Slot::layer(l, "mlp.w1"), dw1)?;
            dm
        } else {
            // MoE backward: every expert, then router
            hooks.moe_exchange(ctx, (b * cfg.seq * h * 4) as u64)?;
            let probs = probs.expect("moe saved probs");
            let mut dm_acc: Option<TBuf> = None;
            let mut dgates: Vec<(usize, HostTensor)> = Vec::new();
            for e in 0..cfg.experts {
                let mut outs = {
                    let lp = hooks.params().map(|p| &p.layers[l]);
                    let ex = lp.map(|lr| match &lr.mlp {
                        MlpParams::Moe { experts, .. } => &experts[e],
                        _ => unreachable!(),
                    });
                    ctx.call_op(
                        Op::MoeBwd,
                        b,
                        1,
                        &[
                            m.buf.arg(),
                            gates[e].buf.arg(),
                            arg_of(ex.map(|x| &x.w1)),
                            arg_of(ex.map(|x| &x.b1)),
                            arg_of(ex.map(|x| &x.w2)),
                            dx.buf.arg(),
                        ],
                        &[
                            acts,
                            acts,
                            MemCategory::Grads,
                            MemCategory::Grads,
                            MemCategory::Grads,
                        ],
                    )?
                };
                let dw2 = outs.pop().unwrap();
                let db1 = outs.pop().unwrap();
                let dw1 = outs.pop().unwrap();
                let dgate = outs.pop().unwrap();
                let dm_e = outs.pop().unwrap();
                hooks.grad(ctx, Slot::expert(l, e, "w2"), dw2)?;
                hooks.grad(ctx, Slot::expert(l, e, "b1"), db1)?;
                hooks.grad(ctx, Slot::expert(l, e, "w1"), dw1)?;
                if !virt {
                    dgates.push((e, dgate.f().clone()));
                }
                ctx.free(dgate);
                match &mut dm_acc {
                    None => dm_acc = Some(dm_e),
                    Some(acc) => {
                        ctx.accumulate(acc, &dm_e);
                        ctx.free(dm_e);
                    }
                }
            }
            // scatter per-expert dgates back into dprobs, then router bwd
            let dprobs_buf = if virt {
                Buf::Virt(vec![b, cfg.seq, cfg.experts])
            } else {
                Buf::Real(scatter_dgates(&dgates, probs.f()))
            };
            let dprobs = ctx.alloc(acts, dprobs_buf)?;
            let mut outs = {
                let lp = hooks.params().map(|p| &p.layers[l]);
                let wr = lp.map(|lr| match &lr.mlp {
                    MlpParams::Moe { wr, .. } => wr,
                    _ => unreachable!(),
                });
                ctx.call_op(
                    Op::RouterBwd,
                    b,
                    1,
                    &[m.buf.arg(), arg_of(wr), dprobs.buf.arg()],
                    &[acts, MemCategory::Grads],
                )?
            };
            let dwr = outs.pop().unwrap();
            let dm_r = outs.pop().unwrap();
            hooks.grad(ctx, Slot::layer(l, "mlp.wr"), dwr)?;
            ctx.free(dprobs);
            ctx.free(probs);
            let mut dm = dm_acc.unwrap();
            ctx.accumulate(&mut dm, &dm_r);
            ctx.free(dm_r);
            hooks.moe_exchange(ctx, (b * cfg.seq * h * 4) as u64)?;
            dm
        };
        for g in gates {
            ctx.free(g);
        }
        ctx.free(m);

        // ln2 backward; dx gains the ln2-input grad (residual stream)
        {
            let lp = hooks.params().map(|p| &p.layers[l]);
            let mut outs = ctx.call_op(
                Op::LnBwd,
                b,
                1,
                &[
                    x_mid.buf.arg(),
                    arg_of(lp.map(|lr| &lr.ln2_g)),
                    dm_total.buf.arg(),
                ],
                &[acts, MemCategory::Grads, MemCategory::Grads],
            )?;
            let db = outs.pop().unwrap();
            let dg = outs.pop().unwrap();
            let dx_ln = outs.pop().unwrap();
            hooks.grad(ctx, Slot::layer(l, "ln2_b"), db)?;
            hooks.grad(ctx, Slot::layer(l, "ln2_g"), dg)?;
            ctx.accumulate(&mut dx, &dx_ln);
            ctx.free(dx_ln);
        }
        ctx.free(dm_total);
        ctx.free(x_mid);

        // dx is now grad wrt x_mid = x_in + attn_part + bo
        let dbo = bias_grad(ctx, &dx, h)?;
        hooks.grad(ctx, Slot::layer(l, "bo"), dbo)?;

        let da = {
            let lp = hooks.params().map(|p| &p.layers[l]);
            let mut outs = ctx.call_op(
                Op::AttnBwd,
                b,
                1,
                &[
                    a.buf.arg(),
                    arg_of(lp.map(|lr| &lr.wqkv)),
                    arg_of(lp.map(|lr| &lr.bqkv)),
                    arg_of(lp.map(|lr| &lr.wo)),
                    dx.buf.arg(),
                ],
                &[acts, MemCategory::Grads, MemCategory::Grads, MemCategory::Grads],
            )?;
            let dwo = outs.pop().unwrap();
            let dbqkv = outs.pop().unwrap();
            let dwqkv = outs.pop().unwrap();
            let da = outs.pop().unwrap();
            hooks.grad(ctx, Slot::layer(l, "wo"), dwo)?;
            hooks.grad(ctx, Slot::layer(l, "bqkv"), dbqkv)?;
            hooks.grad(ctx, Slot::layer(l, "wqkv"), dwqkv)?;
            da
        };
        ctx.free(a);

        // ln1 backward
        {
            let lp = hooks.params().map(|p| &p.layers[l]);
            let mut outs = ctx.call_op(
                Op::LnBwd,
                b,
                1,
                &[
                    x_in.buf.arg(),
                    arg_of(lp.map(|lr| &lr.ln1_g)),
                    da.buf.arg(),
                ],
                &[acts, MemCategory::Grads, MemCategory::Grads],
            )?;
            let db = outs.pop().unwrap();
            let dg = outs.pop().unwrap();
            let dx_ln = outs.pop().unwrap();
            hooks.grad(ctx, Slot::layer(l, "ln1_b"), db)?;
            hooks.grad(ctx, Slot::layer(l, "ln1_g"), dg)?;
            ctx.accumulate(&mut dx, &dx_ln);
            ctx.free(dx_ln);
        }
        ctx.free(da);
        ctx.free(x_in);
        hooks.unit_end(ctx, Unit::Layer(l), Phase::Bwd)?;
    }

    // embedding backward
    hooks.unit_begin(ctx, Unit::Emb, Phase::Bwd)?;
    {
        let mut outs = ctx.call_op(
            Op::EmbBwd,
            b,
            1,
            &[ids.buf.arg(), dx.buf.arg()],
            &[MemCategory::Grads, MemCategory::Grads],
        )?;
        let dwpe = outs.pop().unwrap();
        let dwte = outs.pop().unwrap();
        hooks.grad(ctx, Slot::global("wpe"), dwpe)?;
        hooks.grad(ctx, Slot::global("wte"), dwte)?;
    }
    hooks.unit_end(ctx, Unit::Emb, Phase::Bwd)?;
    ctx.free(dx);
    ctx.free(ids);

    Ok(loss)
}
