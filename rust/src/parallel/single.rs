//! The "unlimited-memory idealized computer" baseline (paper §1, §5.3):
//! one device, whole model, whole batch. This is both the memory ideal
//! every Table-1 row is measured against and the numeric oracle the
//! distributed engines' gradients are checked against.

use anyhow::Result;

use crate::comm::RingPort;
use crate::memory::tracker::MemCategory;
use crate::model::ModelParams;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

use super::common::{Batch, RankCtx, TBuf};
use super::dense::{dense_step, DenseHooks, Phase, Slot, Unit};
use super::RankEngine;

/// The single-device participant (the ring has exactly one rank).
pub struct SingleRank {
    hooks: SingleHooks,
}

struct SingleHooks {
    /// None in virtual mode.
    params: Option<ModelParams>,
    grads: Option<ModelParams>,
}

/// Add a grad buffer into a named tensor of a ModelParams (shared by the
/// single / ddp / fsdp hooks).
pub(crate) fn grad_into(grads: &mut ModelParams, slot: Slot, src: &TBuf) {
    resolve_mut(grads, slot).add_assign(src.f());
}

/// Resolve a slot to its tensor within a ModelParams.
pub(crate) fn resolve_mut(p: &mut ModelParams, slot: Slot) -> &mut HostTensor {
    use crate::model::MlpParams;
    match (slot.layer, slot.expert, slot.name) {
        (None, None, "wte") => &mut p.wte,
        (None, None, "wpe") => &mut p.wpe,
        (None, None, "lnf_g") => &mut p.lnf_g,
        (None, None, "lnf_b") => &mut p.lnf_b,
        (None, None, "wlm") => &mut p.wlm,
        (Some(l), None, name) => {
            let lp = &mut p.layers[l];
            match name {
                "ln1_g" => &mut lp.ln1_g,
                "ln1_b" => &mut lp.ln1_b,
                "wqkv" => &mut lp.wqkv,
                "bqkv" => &mut lp.bqkv,
                "wo" => &mut lp.wo,
                "bo" => &mut lp.bo,
                "ln2_g" => &mut lp.ln2_g,
                "ln2_b" => &mut lp.ln2_b,
                "mlp.w1" => match &mut lp.mlp {
                    MlpParams::Dense { w1, .. } => w1,
                    _ => panic!("mlp.w1 on moe layer"),
                },
                "mlp.b1" => match &mut lp.mlp {
                    MlpParams::Dense { b1, .. } => b1,
                    _ => panic!("mlp.b1 on moe layer"),
                },
                "mlp.w2" => match &mut lp.mlp {
                    MlpParams::Dense { w2, .. } => w2,
                    _ => panic!("mlp.w2 on moe layer"),
                },
                "b2" => match &mut lp.mlp {
                    MlpParams::Dense { b2, .. } => b2,
                    MlpParams::Moe { b2, .. } => b2,
                },
                "mlp.wr" => match &mut lp.mlp {
                    MlpParams::Moe { wr, .. } => wr,
                    _ => panic!("mlp.wr on dense layer"),
                },
                other => panic!("unknown layer slot {other}"),
            }
        }
        (Some(l), Some(e), name) => {
            let lp = &mut p.layers[l];
            let ex = match &mut lp.mlp {
                crate::model::MlpParams::Moe { experts, .. } => &mut experts[e],
                _ => panic!("expert slot on dense layer"),
            };
            match name {
                "w1" => &mut ex.w1,
                "b1" => &mut ex.b1,
                "w2" => &mut ex.w2,
                other => panic!("unknown expert slot {other}"),
            }
        }
        (None, Some(_), _) => panic!("expert slot without layer"),
        (None, None, other) => panic!("unknown global slot {other}"),
    }
}

impl DenseHooks for SingleHooks {
    fn unit_begin(&mut self, _: &mut RankCtx, _: Unit, _: Phase) -> Result<()> {
        Ok(())
    }
    fn unit_end(&mut self, _: &mut RankCtx, _: Unit, _: Phase) -> Result<()> {
        Ok(())
    }
    fn params(&self) -> Option<&ModelParams> {
        self.params.as_ref()
    }
    fn grad(&mut self, ctx: &mut RankCtx, slot: Slot, src: TBuf) -> Result<()> {
        if let (Some(g), false) = (self.grads.as_mut(), src.is_virtual()) {
            grad_into(g, slot, &src);
        }
        ctx.free(src);
        Ok(())
    }
}

impl SingleRank {
    pub fn new(ctx: &mut RankCtx, seed: u64) -> Result<Self> {
        assert_eq!(ctx.n(), 1, "single engine is one worker");
        let virt = ctx.virtual_mode();
        let (params, grads) = if virt {
            (None, None)
        } else {
            let mut rng = Rng::new(seed);
            (
                Some(ModelParams::init(ctx.cfg, &mut rng)),
                Some(ModelParams::zeros_like(ctx.cfg)),
            )
        };
        // persistent weight + grad residency
        let wbytes = ctx.cfg.weight_bytes();
        ctx.tracker.alloc(MemCategory::Weights, wbytes)?;
        ctx.tracker.alloc(MemCategory::Grads, wbytes)?;
        Ok(SingleRank { hooks: SingleHooks { params, grads } })
    }
}

impl RankEngine for SingleRank {
    fn rank(&self) -> usize {
        0
    }

    fn step_local(&mut self, ctx: &mut RankCtx, batch: &Batch) -> Result<f32> {
        let loss = dense_step(ctx, &mut self.hooks, batch)?;
        if let Some(tl) = ctx.timeline.as_deref_mut() {
            tl.barrier();
        }
        Ok(loss)
    }

    fn gather_params_local(&self, _port: &RingPort) -> ModelParams {
        self.hooks.params.clone().expect("no params in virtual mode")
    }

    fn gather_grads_local(&self, _port: &RingPort) -> ModelParams {
        self.hooks.grads.clone().expect("no grads in virtual mode")
    }

    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor)) {
        let (Some(p), Some(g)) = (self.hooks.params.as_mut(), self.hooks.grads.as_ref())
        else {
            return;
        };
        p.zip_mut(g, &mut |_, t, gt| f(t, gt));
    }

    fn zero_grads(&mut self) {
        if let Some(g) = self.hooks.grads.as_mut() {
            g.visit_mut(&mut |_, t| t.data.fill(0.0));
        }
    }

    fn load_full(&mut self, full: &ModelParams) -> Result<()> {
        let Some(p) = self.hooks.params.as_mut() else {
            anyhow::bail!("load_full: no params in virtual mode");
        };
        *p = full.clone();
        Ok(())
    }
}
