//! Pluggable rank-body launchers: HOW the N `RankEngine` participants of
//! a [`ClusterEngine`](super::ClusterEngine) execute.
//!
//! - [`Launcher::Lockstep`] — the deterministic scheduler: rank bodies
//!   run one at a time in round-robin order, yielding only when a `recv`
//!   finds an empty mailbox (threads used as coroutines — stable Rust has
//!   no native coroutines). Execution order depends only on program
//!   structure, so traces, tracker interleavings and failures reproduce
//!   exactly, and a ring deadlock is detected the moment every live rank
//!   is parked. This is the default and what the test suite runs.
//! - [`Launcher::Thread`] — real concurrency: one free-running OS thread
//!   per rank over the `Send` fabric, with an implicit barrier when the
//!   round ends (all threads joined). This is what makes wall-clock
//!   compute/comm overlap measurable instead of modeled.
//!
//! Both launchers produce BIT-IDENTICAL results for every engine: each
//! directed fabric link is FIFO and each rank's program order is fixed,
//! so the data flow — including float reduction order — is independent of
//! scheduling. This holds even for RTP's TRUE async rotation (the Thread
//! launcher eagerly enqueues each outgoing shard before the step's
//! compute): eager vs boundary sends change message TIMING, never a
//! link's send order, so every lane's FIFO delivers the same values. The
//! `launcher_equivalence` integration suite asserts this for all five
//! engines, including async-vs-sync rotation under the Thread launcher.
//!
//! Select globally with `RTP_LAUNCHER=thread` (CI runs the suite under
//! both), or per engine via `EngineOpts::launcher`.

use crate::comm::{LaunchPolicy, RingFabric};

/// Which backend runs the rank bodies. See the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Launcher {
    /// Deterministic round-robin, one rank at a time ("LockstepLauncher").
    Lockstep,
    /// One OS thread per rank, free-running ("ThreadLauncher").
    Thread,
    /// One OS PROCESS per rank ("ProcessLauncher"): ranks are spawned as
    /// `rtp worker` child processes talking over a byte transport (shm
    /// ring or Unix socket — [`TransportKind`](crate::comm::TransportKind)
    /// must not be `Inproc`). Address spaces are genuinely separate, so
    /// overlap and dedup numbers stop sharing an allocator with their
    /// peers. Built by `runtime::proc::ProcessClusterEngine`, not by the
    /// in-process round scheduler — [`Launcher::policy`] panics.
    Process,
}

impl Launcher {
    /// The process-wide default: `RTP_LAUNCHER=thread|threads|threaded`
    /// selects [`Launcher::Thread`]; anything else (or unset) is
    /// [`Launcher::Lockstep`].
    pub fn from_env() -> Launcher {
        std::env::var("RTP_LAUNCHER")
            .ok()
            .and_then(|s| Launcher::parse(&s))
            .unwrap_or(Launcher::Lockstep)
    }

    /// Parse a launcher name (the `RTP_LAUNCHER` / `--launcher`
    /// vocabulary). `None` for unknown names.
    pub fn parse(s: &str) -> Option<Launcher> {
        match s {
            "lockstep" => Some(Launcher::Lockstep),
            "thread" | "threads" | "threaded" => Some(Launcher::Thread),
            "process" | "processes" => Some(Launcher::Process),
            _ => None,
        }
    }

    pub fn policy(&self) -> LaunchPolicy {
        match self {
            Launcher::Lockstep => LaunchPolicy::Lockstep,
            Launcher::Thread => LaunchPolicy::Threaded,
            Launcher::Process => panic!(
                "Launcher::Process has no in-process round policy: rank \
                 bodies run in child processes (runtime::proc)"
            ),
        }
    }

    /// Does this launcher run rank bodies concurrently, so a
    /// [`CommStream`](crate::comm::CommStream) hop issued before a
    /// compute closure genuinely travels WHILE the compute runs? Lockstep
    /// serializes ranks, so overlap there is modeled-only and streams
    /// degrade to synchronous boundary hops (preserving determinism and
    /// launcher bit-identity).
    pub fn overlaps_comm(&self) -> bool {
        matches!(self, Launcher::Thread | Launcher::Process)
    }

    /// Run one closure per rank to completion under this launcher's
    /// scheduling policy; returns per-rank results in rank order. Panics
    /// in any rank body poison the round and re-raise here.
    pub fn run<'env, T: Send>(
        &self,
        fabric: &RingFabric,
        tasks: Vec<Box<dyn FnOnce() -> T + Send + 'env>>,
    ) -> Vec<T> {
        fabric.run_round(self.policy(), tasks)
    }

    /// [`Launcher::run`] without the panic re-raise: the caller inspects
    /// per-rank `thread::Result`s (used by the step path to prefer a
    /// rank's orderly `Err` — e.g. a simulated OOM — over the secondary
    /// poisoned-round panics it caused in blocked peers).
    pub fn try_run<'env, T: Send>(
        &self,
        fabric: &RingFabric,
        tasks: Vec<Box<dyn FnOnce() -> T + Send + 'env>>,
    ) -> Vec<std::thread::Result<T>> {
        fabric.try_round(self.policy(), tasks)
    }
}

impl std::fmt::Display for Launcher {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Launcher::Lockstep => "lockstep",
            Launcher::Thread => "thread",
            Launcher::Process => "process",
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_launchers_run_rank_bodies_to_completion() {
        for launcher in [Launcher::Lockstep, Launcher::Thread] {
            let fab = RingFabric::new(3);
            let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..3)
                .map(|r| {
                    let port = fab.port(r);
                    Box::new(move || {
                        port.send(port.next(), r + 100);
                        port.recv::<usize>(port.prev())
                    }) as Box<dyn FnOnce() -> usize + Send>
                })
                .collect();
            let got = launcher.run(&fab, tasks);
            assert_eq!(got, vec![102, 100, 101], "{launcher}");
        }
    }

    #[test]
    fn env_default_is_lockstep() {
        // RTP_LAUNCHER is not set in the test env
        if std::env::var("RTP_LAUNCHER").is_err() {
            assert_eq!(Launcher::from_env(), Launcher::Lockstep);
        }
    }
}
