//! The cluster-level facade: one [`Engine`] object over N per-rank
//! [`RankEngine`] participants and a [`Launcher`].
//!
//! `ClusterEngine` owns the facade [`Ctx`] (per-worker trackers, trace,
//! timeline, rank 0's executor) and, for each `step`, carves it into
//! per-rank [`RankCtx`] views: rank `w` gets ITS tracker, ITS executor
//! and ITS fabric port; rank 0 additionally gets the timeline and the
//! lead role for once-per-collective trace events. The launcher then runs
//! all rank bodies to completion — serialized round-robin (`Lockstep`) or
//! one OS thread per rank (`Thread`) — and the facade reassembles the
//! cluster view (trace back in place, fabric drained, mean loss).
//!
//! Existing callers (trainer, optimizer, benches, examples, tests) keep
//! driving the old `Engine` trait unchanged; the SPMD decomposition is
//! invisible from the outside except that it now actually exists.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::comm::SchedPolicy;
use crate::memory::tracker::MemTracker;
use crate::model::ModelParams;
use crate::runtime::fault::FaultInjector;
use crate::runtime::Exec;
use crate::tensor::HostTensor;

use super::common::{Batch, Ctx, RankCtx};
use super::launcher::Launcher;
use super::{Engine, RankEngine};

pub struct ClusterEngine {
    ctx: Ctx,
    /// Executors for ranks 1..n (rank 0 borrows `ctx.exec`).
    extra_execs: Vec<Exec>,
    ranks: Vec<Box<dyn RankEngine>>,
    pub launcher: Launcher,
    /// Engine-level wish for true async comm streams; effective only when
    /// the launcher actually overlaps (`launcher.overlaps_comm()`).
    pub async_rotation: bool,
    /// Hop-level scheduling policy for the background collective engine.
    pub sched_policy: SchedPolicy,
    /// Gradient-bucketing size target (`None` = monolithic).
    pub bucket_bytes: Option<u64>,
    /// Deterministic fault-injection harness (`None` = no plan).
    fault: Option<Arc<FaultInjector>>,
    /// Steps run through this facade so far — the step index fault plans
    /// are matched against (0-based).
    steps_done: u64,
    name: String,
}

impl ClusterEngine {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        ctx: Ctx,
        extra_execs: Vec<Exec>,
        ranks: Vec<Box<dyn RankEngine>>,
        launcher: Launcher,
        async_rotation: bool,
        sched_policy: SchedPolicy,
        bucket_bytes: Option<u64>,
        fault: Option<Arc<FaultInjector>>,
        name: String,
    ) -> Self {
        assert_eq!(ranks.len(), ctx.par.workers, "one rank engine per worker");
        assert_eq!(
            extra_execs.len(),
            ranks.len() - 1,
            "one executor per rank (rank 0 uses ctx.exec)"
        );
        ClusterEngine {
            ctx,
            extra_execs,
            ranks,
            launcher,
            async_rotation,
            sched_policy,
            bucket_bytes,
            fault,
            steps_done: 0,
            name,
        }
    }

    /// Per-rank engine access (launcher-equivalence tests).
    pub fn rank_engines(&self) -> &[Box<dyn RankEngine>] {
        &self.ranks
    }

    /// Steps run through this facade so far (global coordinates after a
    /// [`set_step_base`](Engine::set_step_base) rebase).
    pub fn steps_done(&self) -> u64 {
        self.steps_done
    }
}

impl Engine for ClusterEngine {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn step(&mut self, batch: &Batch) -> Result<f32> {
        let n = self.ctx.par.workers;
        if let Some(f) = &self.fault {
            f.begin_step(self.steps_done);
        }
        self.steps_done += 1;
        if let Some(tl) = self.ctx.timeline.as_mut() {
            tl.reset();
        }
        let fabric = self.ctx.cluster.fabric().clone();
        // the trace moves into a mutex for the round (rank bodies on
        // threads share it), then back into the cluster
        let trace = Mutex::new(std::mem::take(&mut self.ctx.cluster.trace));
        let trace_on = trace.lock().unwrap().enabled;
        // true async comm streams only when rank bodies actually run
        // concurrently; under Lockstep the streams degrade to the
        // deterministic synchronous hops
        let async_comm = self.async_rotation && self.launcher.overlaps_comm();

        let results: Vec<std::thread::Result<Result<f32>>> = {
            let cfg = &self.ctx.cfg;
            let par = &self.ctx.par;
            let ports: Vec<_> = self
                .ctx
                .cluster
                .workers
                .iter()
                .map(|w| w.port.clone())
                .collect();
            // split the facade into disjoint per-rank mutable views
            let mut exec_refs: Vec<&mut Exec> = Vec::with_capacity(n);
            exec_refs.push(&mut self.ctx.exec);
            for e in self.extra_execs.iter_mut() {
                exec_refs.push(e);
            }
            let tracker_refs: Vec<&mut MemTracker> = self
                .ctx
                .cluster
                .workers
                .iter_mut()
                .map(|w| &mut w.tracker)
                .collect();
            let mut timeline = self.ctx.timeline.as_mut();
            let mut ctxs: Vec<RankCtx> = Vec::with_capacity(n);
            for (rank, (exec, tracker)) in
                exec_refs.into_iter().zip(tracker_refs).enumerate()
            {
                ctxs.push(RankCtx {
                    rank,
                    cfg,
                    par,
                    exec,
                    tracker,
                    port: ports[rank].clone(),
                    timeline: if rank == 0 { timeline.take() } else { None },
                    trace_log: &trace,
                    trace_on,
                    async_comm,
                    sched_policy: self.sched_policy,
                    bucket_bytes: self.bucket_bytes,
                    fault: self.fault.clone(),
                });
            }
            let tasks: Vec<Box<dyn FnOnce() -> Result<f32> + Send + '_>> = self
                .ranks
                .iter_mut()
                .zip(ctxs)
                .map(|(r, mut c)| {
                    let fab = fabric.clone();
                    Box::new(move || {
                        let out = r.step_local(&mut c, batch);
                        if let Err(e) = &out {
                            // orderly abort (e.g. simulated OOM): wake
                            // peers blocked on this rank's messages so
                            // the round unwinds instead of hanging
                            fab.abort_round(&format!(
                                "rank {} aborted its step: {e:#}",
                                r.rank()
                            ));
                        }
                        out
                    }) as Box<dyn FnOnce() -> Result<f32> + Send + '_>
                })
                .collect();
            self.launcher.try_run(&fabric, tasks)
        };
        self.ctx.cluster.trace = trace.into_inner().unwrap();

        // prefer a rank's orderly Err (OOM & co.) over the secondary
        // poisoned-round panics it caused in peers blocked on the fabric
        let mut loss_sum = 0.0;
        let mut first_err = None;
        let mut first_panic = None;
        for res in results {
            match res {
                Ok(Ok(loss)) => loss_sum += loss,
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(p) => {
                    first_panic.get_or_insert(p);
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        if let Some(p) = first_panic {
            // a typed rank death (injected kill, watchdog timeout, comm
            // thread death) was recorded in the round control block by
            // whichever detector saw it first: surface it as ONE typed
            // error instead of resuming the secondary poisoned-round
            // panics it caused in peers
            if let Some(f) = fabric.rank_failure() {
                return Err(anyhow::Error::new(f));
            }
            std::panic::resume_unwind(p);
        }
        debug_assert_eq!(
            fabric.in_flight(),
            0,
            "step left ring-fabric messages in flight"
        );
        Ok(loss_sum / n as f32)
    }

    fn gather_params(&self) -> ModelParams {
        let fabric = self.ctx.cluster.fabric().clone();
        let tasks: Vec<Box<dyn FnOnce() -> ModelParams + Send + '_>> = self
            .ranks
            .iter()
            .map(|r| {
                let port = self.ctx.cluster.workers[r.rank()].port.clone();
                Box::new(move || r.gather_params_local(&port))
                    as Box<dyn FnOnce() -> ModelParams + Send + '_>
            })
            .collect();
        let mut outs = self.launcher.run(&fabric, tasks);
        debug_assert_eq!(fabric.in_flight(), 0);
        outs.swap_remove(0)
    }

    fn gather_grads(&self) -> ModelParams {
        let fabric = self.ctx.cluster.fabric().clone();
        let tasks: Vec<Box<dyn FnOnce() -> ModelParams + Send + '_>> = self
            .ranks
            .iter()
            .map(|r| {
                let port = self.ctx.cluster.workers[r.rank()].port.clone();
                Box::new(move || r.gather_grads_local(&port))
                    as Box<dyn FnOnce() -> ModelParams + Send + '_>
            })
            .collect();
        let mut outs = self.launcher.run(&fabric, tasks);
        debug_assert_eq!(fabric.in_flight(), 0);
        outs.swap_remove(0)
    }

    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor)) {
        for r in &mut self.ranks {
            r.visit_owned(f);
        }
    }

    fn zero_grads(&mut self) {
        for r in &mut self.ranks {
            r.zero_grads();
        }
    }

    fn load_full(&mut self, full: &ModelParams) -> Result<()> {
        // comm-free: each rank replays its constructor's sharding math
        // locally, so no fabric round (and no launcher) is needed
        for r in &mut self.ranks {
            r.load_full(full)?;
        }
        Ok(())
    }

    fn set_step_base(&mut self, base: u64) {
        // a rebuilt cluster resumes at the run's GLOBAL step index, so
        // fault-plan step coordinates keep meaning "training step s"
        // across elastic recoveries
        self.steps_done = base;
    }

    fn ctx(&self) -> &Ctx {
        &self.ctx
    }
    fn ctx_mut(&mut self) -> &mut Ctx {
        &mut self.ctx
    }
}
