//! Fully-Sharded Data Parallel (Zhao et al. 2023) — the paper's primary
//! memory baseline (Table 1 row 5: `max(W,G)·(N-1)` duplication).
//!
//! Every unit's parameters live as a FlatParameter sharded across workers.
//! `unit_begin` allgathers the full unit (blocking for the first unit —
//! the startup penalty the paper contrasts RTP against in §3.4.3 — then
//! eagerly prefetched one unit ahead); `unit_end` reshards. In backward,
//! a full-unit gradient staging buffer is reduce-scattered so each worker
//! retains only its grad shard.
//!
//! `Granularity::Model` treats the whole model as ONE unit — the paper's
//! Table-1 worst case, used by the `table1_memory` bench; `Layer` is the
//! realistic per-layer wrapping used everywhere else (the delta between
//! the two is an ablation in EXPERIMENTS.md).

use std::collections::HashMap;

use anyhow::Result;

use crate::comm::CommPrim;
use crate::config::ModelCfg;
use crate::flat_param::FlatLayout;
use crate::memory::tracker::MemCategory;
use crate::model::ModelParams;
use crate::perfmodel::Token;
use crate::runtime::Buf;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

use super::common::{Batch, Ctx, TBuf};
use super::dense::{dense_step, DenseHooks, Phase, Slot, Unit};
use super::single::resolve_mut;
use super::Engine;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// One FlatParameter per layer (+ emb + final) — realistic FSDP.
    Layer,
    /// The whole model as a single unit — paper Table 1's formula.
    Model,
}

/// The parameter list of one unit, in canonical flat order.
pub fn unit_param_list(cfg: &ModelCfg, unit: Unit) -> Vec<(Slot, Vec<usize>)> {
    let (v, h, s, f) = (cfg.vocab, cfg.hidden, cfg.seq, cfg.ffn);
    match unit {
        Unit::Emb => vec![
            (Slot::global("wte"), vec![v, h]),
            (Slot::global("wpe"), vec![s, h]),
        ],
        Unit::Final => vec![
            (Slot::global("lnf_g"), vec![h]),
            (Slot::global("lnf_b"), vec![h]),
            (Slot::global("wlm"), vec![h, v]),
        ],
        Unit::Layer(l) => {
            let mut p = vec![
                (Slot::layer(l, "ln1_g"), vec![h]),
                (Slot::layer(l, "ln1_b"), vec![h]),
                (Slot::layer(l, "wqkv"), vec![h, 3 * h]),
                (Slot::layer(l, "bqkv"), vec![3 * h]),
                (Slot::layer(l, "wo"), vec![h, h]),
                (Slot::layer(l, "bo"), vec![h]),
                (Slot::layer(l, "ln2_g"), vec![h]),
                (Slot::layer(l, "ln2_b"), vec![h]),
            ];
            if cfg.is_moe() {
                p.push((Slot::layer(l, "mlp.wr"), vec![h, cfg.experts]));
                for e in 0..cfg.experts {
                    p.push((Slot::expert(l, e, "w1"), vec![h, cfg.expert_ffn]));
                    p.push((Slot::expert(l, e, "b1"), vec![cfg.expert_ffn]));
                    p.push((Slot::expert(l, e, "w2"), vec![cfg.expert_ffn, h]));
                }
            } else {
                p.push((Slot::layer(l, "mlp.w1"), vec![h, f]));
                p.push((Slot::layer(l, "mlp.b1"), vec![f]));
                p.push((Slot::layer(l, "mlp.w2"), vec![f, h]));
            }
            p.push((Slot::layer(l, "b2"), vec![h]));
            p
        }
    }
}

fn layout_of(cfg: &ModelCfg, unit: Unit, n: usize) -> (FlatLayout, Vec<Slot>) {
    let list = unit_param_list(cfg, unit);
    let named: Vec<(&str, Vec<usize>)> =
        list.iter().map(|(s, shape)| (s.name, shape.clone())).collect();
    (
        FlatLayout::new(&named, n),
        list.into_iter().map(|(s, _)| s).collect(),
    )
}

fn unit_index(unit: Unit) -> usize {
    match unit {
        Unit::Emb => 0,
        Unit::Layer(l) => l + 1,
        Unit::Final => usize::MAX, // remapped by UnitTable
    }
}

/// Successor unit for prefetch, per phase order.
fn successor(unit: Unit, phase: Phase, layers: usize) -> Option<Unit> {
    match (phase, unit) {
        (Phase::Fwd, Unit::Emb) => Some(if layers > 0 { Unit::Layer(0) } else { Unit::Final }),
        (Phase::Fwd, Unit::Layer(l)) if l + 1 < layers => Some(Unit::Layer(l + 1)),
        (Phase::Fwd, Unit::Layer(_)) => Some(Unit::Final),
        (Phase::Fwd, Unit::Final) => None,
        (Phase::Bwd, Unit::Final) if layers > 0 => Some(Unit::Layer(layers - 1)),
        (Phase::Bwd, Unit::Final) => Some(Unit::Emb),
        (Phase::Bwd, Unit::Layer(l)) if l > 0 => Some(Unit::Layer(l - 1)),
        (Phase::Bwd, Unit::Layer(_)) => Some(Unit::Emb),
        (Phase::Bwd, Unit::Emb) => None,
    }
}

struct UnitState {
    layout: FlatLayout,
    slots: Vec<Slot>,
    /// Per-worker parameter shards (1-D) — None in virtual mode.
    param_shards: Option<Vec<HostTensor>>,
    /// Per-worker gradient shards (1-D) — None in virtual mode.
    grad_shards: Option<Vec<HostTensor>>,
    /// Residency: (worker -> full-weights comm buffer).
    resident: HashMap<usize, TBuf>,
    /// Backward grad staging buffers: worker -> (tracker buf).
    staging: HashMap<usize, TBuf>,
    /// Host-side staged full grads per worker (kept past the tracked
    /// buffer's life because workers run sequentially in this process;
    /// the DEVICE buffer is freed at unit_end like real FSDP).
    staged_grads: HashMap<usize, Vec<f32>>,
}

struct FsdpHooks {
    units: Vec<Unit>,
    states: Vec<UnitState>,
    /// Full-weight scratch the walk reads (real mode): one per worker.
    scratch: Vec<ModelParams>,
    granularity: Granularity,
    layers: usize,
    /// In-flight prefetch: (unit, token).
    prefetch: Option<(Unit, Token)>,
    /// In-flight reduce-scatters (waited at the step barrier — they
    /// overlap the next unit's backward compute, as real FSDP does).
    pending_rs: Vec<Token>,
}

impl FsdpHooks {
    fn state_idx(&self, unit: Unit) -> usize {
        match self.granularity {
            Granularity::Model => 0,
            Granularity::Layer => match unit {
                Unit::Final => self.states.len() - 1,
                u => unit_index(u),
            },
        }
    }

    /// Allgather + materialize one unit's full weights on worker `w`.
    /// Real mode runs the chunked ring allgather through every rank's own
    /// fabric port (symmetric SPMD — all ranks step the same N-1 hop
    /// schedule) and keeps rank `w`'s reconstruction.
    fn gather_unit(&mut self, ctx: &mut Ctx, w: usize, sidx: usize) -> Result<()> {
        let full_bytes = self.states[sidx].layout.full_bytes();
        let tb = ctx.alloc(w, MemCategory::CommBuf, Buf::Virt(vec![full_bytes as usize / 4]))?;
        // real mode: reconstruct + unpack into the walk's scratch view
        if self.states[sidx].param_shards.is_some() {
            let ports = ctx.ports();
            let st = &self.states[sidx];
            let shards = st.param_shards.as_ref().unwrap();
            let flats: Vec<Vec<f32>> = shards.iter().map(|t| t.data.clone()).collect();
            let fulls = st.layout.allgather_via(ports, &flats);
            let tensors = st.layout.unpack(&fulls[w]);
            for (slot, t) in st.slots.clone().into_iter().zip(tensors) {
                *resolve_mut(&mut self.scratch[w], slot) = t;
            }
        }
        self.states[sidx].resident.insert(w, tb);
        Ok(())
    }
}

impl DenseHooks for FsdpHooks {
    fn unit_begin(&mut self, ctx: &mut Ctx, w: usize, unit: Unit, phase: Phase) -> Result<()> {
        let sidx = self.state_idx(unit);
        if !self.states[sidx].resident.contains_key(&w) {
            // timeline: consume a matching prefetch or block on allgather
            if w == 0 {
                let full_bytes = self.states[sidx].layout.full_bytes();
                let hit = matches!(self.prefetch, Some((u, _)) if u == unit);
                if hit {
                    let (_, tok) = self.prefetch.take().unwrap();
                    ctx.charge_wait(Some(tok));
                } else {
                    ctx.charge_comm("allgather", CommPrim::AllGather, full_bytes);
                }
            }
            self.gather_unit(ctx, w, sidx)?;
        }
        // issue the next unit's prefetch (layer granularity only)
        if w == 0 && self.granularity == Granularity::Layer {
            if let Some(next) = successor(unit, phase, self.layers) {
                let nidx = self.state_idx(next);
                let already = self.states[nidx].resident.contains_key(&0)
                    || matches!(self.prefetch, Some((u, _)) if u == next);
                if !already {
                    if let Some(tok) = ctx.charge_comm_async_eager(
                        "prefetch-allgather",
                        CommPrim::AllGather,
                        self.states[nidx].layout.full_bytes(),
                    ) {
                        self.prefetch = Some((next, tok));
                    }
                }
            }
        }
        // backward: allocate the full-unit gradient staging buffer
        if phase == Phase::Bwd && !self.states[sidx].staging.contains_key(&w) {
            let elems = self.states[sidx].layout.padded;
            let tb = ctx.alloc(w, MemCategory::CommBuf, Buf::Virt(vec![elems]))?;
            self.states[sidx].staging.insert(w, tb);
            if self.states[sidx].param_shards.is_some() {
                self.states[sidx].staged_grads.insert(w, vec![0.0; elems]);
            }
        }
        Ok(())
    }

    fn unit_end(&mut self, ctx: &mut Ctx, w: usize, unit: Unit, phase: Phase) -> Result<()> {
        if self.granularity == Granularity::Model {
            // whole-model unit stays resident for the entire step
            return Ok(());
        }
        let sidx = self.state_idx(unit);
        // reshard: free the full weights
        if let Some(tb) = self.states[sidx].resident.remove(&w) {
            ctx.free(tb);
        }
        if phase == Phase::Bwd {
            // reduce-scatter the staged grads asynchronously — it overlaps
            // the next unit's backward compute (real FSDP's behavior); the
            // step barrier waits on all of them.
            if w == 0 {
                if let Some(tok) = ctx.charge_comm_async(
                    "reduce-scatter",
                    CommPrim::ReduceScatter,
                    self.states[sidx].layout.full_bytes(),
                ) {
                    self.pending_rs.push(tok);
                }
            }
            if let Some(tb) = self.states[sidx].staging.remove(&w) {
                ctx.free(tb);
            }
        }
        Ok(())
    }

    fn params(&self, w: usize) -> Option<&ModelParams> {
        self.scratch.get(w)
    }

    fn moe_exchange(&mut self, ctx: &mut Ctx, w: usize, bytes: u64) -> Result<()> {
        if w == 0 && ctx.n() > 1 {
            ctx.charge_comm("all-to-all", CommPrim::AllToAll, bytes);
        }
        Ok(())
    }

    fn grad(&mut self, ctx: &mut Ctx, w: usize, slot: Slot, src: TBuf) -> Result<()> {
        let sidx = self.state_idx(slot.unit());
        if !src.is_virtual() {
            let st = &mut self.states[sidx];
            let k = st.slots.iter().position(|s| *s == slot).expect("slot in unit");
            let spec = &st.layout.specs[k];
            if let Some(stage) = st.staged_grads.get_mut(&w) {
                for (d, v) in stage[spec.offset..spec.offset + spec.len()]
                    .iter_mut()
                    .zip(&src.f().data)
                {
                    *d += v;
                }
            }
        }
        ctx.free(src);
        Ok(())
    }
}

pub struct FsdpEngine {
    pub ctx: Ctx,
    hooks: FsdpHooks,
    last_loss: f32,
}

impl FsdpEngine {
    pub fn new(mut ctx: Ctx, seed: u64, granularity: Granularity) -> Result<Self> {
        let n = ctx.n();
        let cfg = ctx.cfg.clone();
        let virt = ctx.virtual_mode();
        let units = match granularity {
            Granularity::Layer => Unit::all(cfg.layers),
            Granularity::Model => Unit::all(cfg.layers), // one merged layout below
        };

        // build unit states
        let mut states = Vec::new();
        match granularity {
            Granularity::Layer => {
                for &u in &units {
                    let (layout, slots) = layout_of(&cfg, u, n);
                    states.push(UnitState {
                        layout,
                        slots,
                        param_shards: None,
                        grad_shards: None,
                        resident: HashMap::new(),
                        staging: HashMap::new(),
                        staged_grads: HashMap::new(),
                    });
                }
            }
            Granularity::Model => {
                // single layout concatenating every unit's params
                let mut all: Vec<(Slot, Vec<usize>)> = Vec::new();
                for &u in &units {
                    all.extend(unit_param_list(&cfg, u));
                }
                let named: Vec<(&str, Vec<usize>)> =
                    all.iter().map(|(s, sh)| (s.name, sh.clone())).collect();
                states.push(UnitState {
                    layout: FlatLayout::new(&named, n),
                    slots: all.into_iter().map(|(s, _)| s).collect(),
                    param_shards: None,
                    grad_shards: None,
                    resident: HashMap::new(),
                    staging: HashMap::new(),
                    staged_grads: HashMap::new(),
                });
            }
        }

        // initialize shards from a full seed model (real mode)
        if !virt {
            let full = ModelParams::init(&cfg, &mut Rng::new(seed));
            let mut fullp = full;
            for st in &mut states {
                let tensors: Vec<&HostTensor> = st
                    .slots
                    .iter()
                    .map(|&s| &*resolve_mut(&mut fullp, s) as *const HostTensor)
                    .collect::<Vec<_>>()
                    .into_iter()
                    // SAFETY: resolve_mut only borrows disjoint fields; we
                    // immediately downgrade to shared refs.
                    .map(|p| unsafe { &*p })
                    .collect();
                let flat = st.layout.pack(&tensors);
                st.param_shards = Some(
                    st.layout
                        .shards(&flat)
                        .into_iter()
                        .map(|v| HostTensor::from_vec(&[v.len()], v))
                        .collect(),
                );
                st.grad_shards = Some(
                    (0..n)
                        .map(|_| HostTensor::zeros(&[st.layout.shard_len()]))
                        .collect(),
                );
            }
        }

        // persistent residency: shard weights + shard grads per worker
        let shard_bytes: u64 = states.iter().map(|s| s.layout.shard_bytes()).sum();
        for w in 0..n {
            ctx.cluster.tracker(w).alloc(MemCategory::Weights, shard_bytes)?;
            ctx.cluster.tracker(w).alloc(MemCategory::Grads, shard_bytes)?;
        }

        let scratch = if virt {
            Vec::new()
        } else {
            (0..n).map(|_| ModelParams::zeros_like(&cfg)).collect()
        };
        Ok(FsdpEngine {
            ctx,
            hooks: FsdpHooks {
                units,
                states,
                scratch,
                granularity,
                layers: cfg.layers,
                prefetch: None,
                pending_rs: Vec::new(),
            },
            last_loss: 0.0,
        })
    }

    /// Post-step: mean-reduce staged full grads into the shard grads
    /// (chunked ring reduce-scatter over the rank-local ports) and release
    /// whole-model residency (Model granularity).
    fn finish_step(&mut self) -> Result<()> {
        let n = self.ctx.n();
        // owned copy: the loop below also needs `self.ctx` mutably
        let ports: Vec<crate::comm::RingPort> = self.ctx.ports().to_vec();
        for st in &mut self.hooks.states {
            if st.param_shards.is_some() && !st.staged_grads.is_empty() {
                let fulls: Vec<Vec<f32>> = (0..n)
                    .map(|w| st.staged_grads.remove(&w).expect("staged grads"))
                    .collect();
                let shards = st.layout.reduce_scatter_via(&ports, &fulls);
                let gs = st.grad_shards.as_mut().unwrap();
                for (g, s) in gs.iter_mut().zip(shards) {
                    for (a, b) in g.data.iter_mut().zip(s) {
                        *a += b / n as f32;
                    }
                }
            }
            st.staged_grads.clear();
            // Model granularity: release residency + staging now
            let workers: Vec<usize> = st.resident.keys().copied().collect();
            for w in workers {
                let tb = st.resident.remove(&w).unwrap();
                self.ctx.free(tb);
            }
            let workers: Vec<usize> = st.staging.keys().copied().collect();
            for w in workers {
                let tb = st.staging.remove(&w).unwrap();
                if w == 0 {
                    self.ctx.charge_comm(
                        "reduce-scatter",
                        CommPrim::ReduceScatter,
                        st.layout.full_bytes(),
                    );
                }
                self.ctx.free(tb);
            }
        }
        self.hooks.prefetch = None;
        if let Some(tl) = self.ctx.timeline.as_mut() {
            for tok in self.hooks.pending_rs.drain(..) {
                tl.wait(tok);
            }
        }
        Ok(())
    }
}

impl Engine for FsdpEngine {
    fn name(&self) -> String {
        match self.hooks.granularity {
            Granularity::Layer => "fsdp".to_string(),
            Granularity::Model => "fsdp-model-unit".to_string(),
        }
    }

    fn step(&mut self, batch: &Batch) -> Result<f32> {
        let n = self.ctx.n();
        if let Some(tl) = self.ctx.timeline.as_mut() {
            tl.reset();
        }
        let mut loss_sum = 0.0;
        for w in 0..n {
            let shard = batch.shard(w, n);
            loss_sum += dense_step(&mut self.ctx, &mut self.hooks, w, &shard)?;
        }
        self.finish_step()?;
        if let Some(tl) = self.ctx.timeline.as_mut() {
            tl.barrier();
        }
        debug_assert_eq!(
            self.ctx.cluster.fabric().in_flight(),
            0,
            "fsdp step left ring-fabric messages in flight"
        );
        self.last_loss = loss_sum / n as f32;
        Ok(self.last_loss)
    }

    fn gather_params(&self) -> ModelParams {
        let ports = self.ctx.ports();
        let mut out = ModelParams::zeros_like(&self.ctx.cfg);
        for st in &self.hooks.states {
            let shards = st.param_shards.as_ref().expect("virtual mode");
            let flats: Vec<Vec<f32>> = shards.iter().map(|t| t.data.clone()).collect();
            let full = st.layout.allgather_via(ports, &flats).swap_remove(0);
            for (slot, t) in st.slots.iter().zip(st.layout.unpack(&full)) {
                *resolve_mut(&mut out, *slot) = t;
            }
        }
        out
    }

    fn gather_grads(&self) -> ModelParams {
        let ports = self.ctx.ports();
        let mut out = ModelParams::zeros_like(&self.ctx.cfg);
        for st in &self.hooks.states {
            let shards = st.grad_shards.as_ref().expect("virtual mode");
            let flats: Vec<Vec<f32>> = shards.iter().map(|t| t.data.clone()).collect();
            let full = st.layout.allgather_via(ports, &flats).swap_remove(0);
            for (slot, t) in st.slots.iter().zip(st.layout.unpack(&full)) {
                *resolve_mut(&mut out, *slot) = t;
            }
        }
        out
    }

    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor)) {
        for st in &mut self.hooks.states {
            let (Some(ps), Some(gs)) = (st.param_shards.as_mut(), st.grad_shards.as_ref())
            else {
                return;
            };
            for (p, g) in ps.iter_mut().zip(gs) {
                f(p, g);
            }
        }
    }

    fn zero_grads(&mut self) {
        for st in &mut self.hooks.states {
            if let Some(gs) = st.grad_shards.as_mut() {
                for g in gs {
                    g.data.fill(0.0);
                }
            }
        }
    }

    fn ctx(&self) -> &Ctx {
        &self.ctx
    }
    fn ctx_mut(&mut self) -> &mut Ctx {
        &mut self.ctx
    }
}
