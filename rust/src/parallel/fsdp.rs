//! Fully-Sharded Data Parallel (Zhao et al. 2023) — the paper's primary
//! memory baseline (Table 1 row 5: `max(W,G)·(N-1)` duplication).
//!
//! Every unit's parameters live as a FlatParameter sharded across ranks;
//! each rank is an independent [`RankEngine`] holding ONE shard per unit.
//! `unit_begin` runs this rank's side of the unit ring-allgather
//! (blocking for the first unit — the startup penalty the paper
//! contrasts RTP against in §3.4.3 — then eagerly prefetched one unit
//! ahead); `unit_end` reshards. In backward, a full-unit gradient staging
//! buffer is reduce-scattered so each rank retains only its grad shard.
//!
//! Since the background-collective-engine PR the prefetch and the
//! backward reduce-scatter are REAL on the data path, not just modeled:
//! each rank owns a [`CollectiveStream`] whose dedicated comm thread
//! (Thread launcher) executes the queued allgathers/reduce-scatters over
//! the fabric's background lanes while the rank body computes — the
//! prefetched unit's weights are already reconstructed when `unit_begin`
//! joins the handle, and the per-unit grad reduce-scatters issued at
//! `unit_end(Bwd)` are joined at the step barrier. Under Lockstep the
//! same stream degrades to deterministic execute-at-join, keeping both
//! launchers bit-identical (asserted by `tests/launcher_equivalence.rs`).
//! All buffers (full-weight reconstruction, grad staging) are recycled
//! across steps, so the whole path performs zero steady-state heap
//! allocations.
//!
//! During backward the stream routinely holds a latency-critical
//! prefetch allgather AND several pending grad reduce-scatters at once —
//! exactly the multi-collective set the comm thread's hop-level
//! scheduler ([`SchedPolicy`](crate::comm::SchedPolicy), plumbed through
//! `EngineOpts::sched_policy`) interleaves: under `RoundRobin`/`Priority`
//! the prefetch stops convoying behind the reduce-scatter queue, without
//! any change to this engine's code or its results (bit-identical across
//! policies by the sub-channel construction in `comm/stream.rs`).
//!
//! Under the old god-view engine every worker re-ran the WHOLE ring
//! allgather once per worker (correct but N× redundant). With per-rank
//! engines each rank runs its own side of ONE allgather per unit — the
//! redundancy collapsed structurally, exactly as a real N-process FSDP
//! launch behaves.
//!
//! `Granularity::Model` treats the whole model as ONE unit — the paper's
//! Table-1 worst case, used by the `table1_memory` bench; `Layer` is the
//! realistic per-layer wrapping used everywhere else (the delta between
//! the two is an ablation in EXPERIMENTS.md).

use std::collections::HashMap;

use anyhow::Result;

use crate::comm::{CollHandle, CollectiveStream, CommPrim, RingPort};
use crate::config::ModelCfg;
use crate::flat_param::FlatLayout;
use crate::memory::tracker::MemCategory;
use crate::model::ModelParams;
use crate::perfmodel::Token;
use crate::runtime::Buf;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

use super::common::{Batch, RankCtx, TBuf};
use super::dense::{dense_step, DenseHooks, Phase, Slot, Unit};
use super::single::resolve_mut;
use super::RankEngine;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// One FlatParameter per layer (+ emb + final) — realistic FSDP.
    Layer,
    /// The whole model as a single unit — paper Table 1's formula.
    Model,
}

/// The parameter list of one unit, in canonical flat order.
pub fn unit_param_list(cfg: &ModelCfg, unit: Unit) -> Vec<(Slot, Vec<usize>)> {
    let (v, h, s, f) = (cfg.vocab, cfg.hidden, cfg.seq, cfg.ffn);
    match unit {
        Unit::Emb => vec![
            (Slot::global("wte"), vec![v, h]),
            (Slot::global("wpe"), vec![s, h]),
        ],
        Unit::Final => vec![
            (Slot::global("lnf_g"), vec![h]),
            (Slot::global("lnf_b"), vec![h]),
            (Slot::global("wlm"), vec![h, v]),
        ],
        Unit::Layer(l) => {
            let mut p = vec![
                (Slot::layer(l, "ln1_g"), vec![h]),
                (Slot::layer(l, "ln1_b"), vec![h]),
                (Slot::layer(l, "wqkv"), vec![h, 3 * h]),
                (Slot::layer(l, "bqkv"), vec![3 * h]),
                (Slot::layer(l, "wo"), vec![h, h]),
                (Slot::layer(l, "bo"), vec![h]),
                (Slot::layer(l, "ln2_g"), vec![h]),
                (Slot::layer(l, "ln2_b"), vec![h]),
            ];
            if cfg.is_moe() {
                p.push((Slot::layer(l, "mlp.wr"), vec![h, cfg.experts]));
                for e in 0..cfg.experts {
                    p.push((Slot::expert(l, e, "w1"), vec![h, cfg.expert_ffn]));
                    p.push((Slot::expert(l, e, "b1"), vec![cfg.expert_ffn]));
                    p.push((Slot::expert(l, e, "w2"), vec![cfg.expert_ffn, h]));
                }
            } else {
                p.push((Slot::layer(l, "mlp.w1"), vec![h, f]));
                p.push((Slot::layer(l, "mlp.b1"), vec![f]));
                p.push((Slot::layer(l, "mlp.w2"), vec![f, h]));
            }
            p.push((Slot::layer(l, "b2"), vec![h]));
            p
        }
    }
}

fn layout_of(cfg: &ModelCfg, unit: Unit, n: usize) -> (FlatLayout, Vec<Slot>) {
    let list = unit_param_list(cfg, unit);
    let named: Vec<(&str, Vec<usize>)> =
        list.iter().map(|(s, shape)| (s.name, shape.clone())).collect();
    (
        FlatLayout::new(&named, n),
        list.into_iter().map(|(s, _)| s).collect(),
    )
}

/// Fold a completed reduce-scatter buffer back into its unit state: add
/// this rank's reduced chunk into the grad shard (mean over N) and
/// retire the buffer into the staging scratch for next step.
fn fold_reduced(st: &mut UnitState, full: Vec<f32>, w: usize, n: usize) {
    let s = st.layout.shard_len();
    if let Some(gs) = st.grad_shard.as_mut() {
        for (a, b) in gs.data.iter_mut().zip(&full[w * s..(w + 1) * s]) {
            *a += b / n as f32;
        }
    }
    st.staged_scratch = Some(full);
}

fn unit_index(unit: Unit) -> usize {
    match unit {
        Unit::Emb => 0,
        Unit::Layer(l) => l + 1,
        Unit::Final => usize::MAX, // remapped by state_idx
    }
}

/// Successor unit for prefetch, per phase order.
fn successor(unit: Unit, phase: Phase, layers: usize) -> Option<Unit> {
    match (phase, unit) {
        (Phase::Fwd, Unit::Emb) => Some(if layers > 0 { Unit::Layer(0) } else { Unit::Final }),
        (Phase::Fwd, Unit::Layer(l)) if l + 1 < layers => Some(Unit::Layer(l + 1)),
        (Phase::Fwd, Unit::Layer(_)) => Some(Unit::Final),
        (Phase::Fwd, Unit::Final) => None,
        (Phase::Bwd, Unit::Final) if layers > 0 => Some(Unit::Layer(layers - 1)),
        (Phase::Bwd, Unit::Final) => Some(Unit::Emb),
        (Phase::Bwd, Unit::Layer(l)) if l > 0 => Some(Unit::Layer(l - 1)),
        (Phase::Bwd, Unit::Layer(_)) => Some(Unit::Emb),
        (Phase::Bwd, Unit::Emb) => None,
    }
}

/// One unit's per-rank state: this rank's shard + transient residency.
struct UnitState {
    layout: FlatLayout,
    slots: Vec<Slot>,
    /// This rank's parameter shard (1-D) — None in virtual mode.
    param_shard: Option<HostTensor>,
    /// This rank's gradient shard (1-D) — None in virtual mode.
    grad_shard: Option<HostTensor>,
    /// Residency: the full-weights comm buffer while the unit is live.
    resident: Option<TBuf>,
    /// Backward grad staging buffer (tracker registration).
    staging: Option<TBuf>,
    /// Host-side staged full grads (alive between backward compute and
    /// the end-of-step reduce-scatter; the DEVICE buffer is freed at
    /// unit_end like real FSDP).
    staged_grads: Option<Vec<f32>>,
    /// Retired staging buffer, reused next step so the backward staging
    /// path performs zero steady-state allocations.
    staged_scratch: Option<Vec<f32>>,
    /// Recycled full-weight reconstruction buffer: moved into the
    /// background allgather at issue, returned at join.
    full_scratch: Option<Vec<f32>>,
}

/// An in-flight unit prefetch: the modeled token (lead rank) plus the
/// real background allgather handle (real mode) — consumed together at
/// the next `unit_begin`.
struct Prefetch {
    unit: Unit,
    sidx: usize,
    tok: Option<Token>,
    gather: Option<CollHandle>,
}

struct FsdpHooks {
    states: Vec<UnitState>,
    /// Full-weight scratch the walk reads (real mode).
    scratch: ModelParams,
    virt: bool,
    granularity: Granularity,
    layers: usize,
    /// In-flight prefetch (modeled token + background data-path gather).
    prefetch: Option<Prefetch>,
    /// In-flight reduce-scatters, modeled side (waited at the step
    /// barrier — they overlap the next unit's backward compute, as real
    /// FSDP does).
    pending_rs: Vec<Token>,
    /// In-flight reduce-scatters, data path: (state idx, handle), joined
    /// at the step barrier in issue order.
    pending_rs_data: Vec<(usize, CollHandle)>,
    /// slot -> (state idx, spec idx): the grad hook runs once per
    /// parameter per step, so the lookup is precomputed at init.
    slot_index: HashMap<Slot, (usize, usize)>,
    /// This rank's background collective engine (created at the first
    /// step, when the launcher's concurrency mode is known).
    coll: Option<CollectiveStream>,
}

impl FsdpHooks {
    fn state_idx(&self, unit: Unit) -> usize {
        match self.granularity {
            Granularity::Model => 0,
            Granularity::Layer => match unit {
                Unit::Final => self.states.len() - 1,
                u => unit_index(u),
            },
        }
    }

    /// Make unit `sidx`'s full weights resident: join `pending` (an
    /// in-flight background prefetch — already reconstructed if the comm
    /// thread kept up) or issue-and-join the allgather now (the blocking
    /// first-unit path), then unpack the reconstruction into this rank's
    /// scratch view. The full buffer is recycled into the state for the
    /// next issue.
    fn gather_unit(
        &mut self,
        ctx: &mut RankCtx,
        sidx: usize,
        pending: Option<CollHandle>,
    ) -> Result<()> {
        let full_bytes = self.states[sidx].layout.full_bytes();
        let tb = ctx.alloc(MemCategory::CommBuf, Buf::Virt(vec![full_bytes as usize / 4]))?;
        let handle = match pending {
            Some(h) => Some(h),
            None => self.issue_gather(sidx),
        };
        if let Some(h) = handle {
            let full = self.coll.as_ref().expect("stream initialized").join(h);
            let st = &self.states[sidx];
            let tensors = st.layout.unpack(&full);
            for (slot, t) in st.slots.clone().into_iter().zip(tensors) {
                *resolve_mut(&mut self.scratch, slot) = t;
            }
            self.states[sidx].full_scratch = Some(full);
        }
        self.states[sidx].resident = Some(tb);
        Ok(())
    }

    /// Issue this rank's side of unit `sidx`'s allgather on the
    /// background engine (real mode only — returns None in virtual mode).
    /// Every rank issues at the same program point, so the comm threads
    /// run the collective together while the rank bodies compute.
    fn issue_gather(&mut self, sidx: usize) -> Option<CollHandle> {
        let st = &mut self.states[sidx];
        let shard = st.param_shard.as_ref()?;
        let buf = st.full_scratch.take().unwrap_or_default();
        let stream = self.coll.as_ref().expect("stream initialized");
        Some(stream.issue_allgather(&shard.data, buf))
    }
}

impl DenseHooks for FsdpHooks {
    fn unit_begin(&mut self, ctx: &mut RankCtx, unit: Unit, phase: Phase) -> Result<()> {
        if self.coll.is_none() && !self.virt {
            // first touch: the launcher's concurrency mode is now known.
            // Virtual mode never moves data, so it never needs the stream
            // (or its comm thread).
            self.coll = Some(ctx.collectives());
        }
        let sidx = self.state_idx(unit);
        if self.states[sidx].resident.is_none() {
            // consume a matching prefetch (modeled: wait on its token;
            // data path: join the background allgather) or block on a
            // fresh allgather — the startup penalty of §3.4.3
            let full_bytes = self.states[sidx].layout.full_bytes();
            let hit = matches!(&self.prefetch, Some(p) if p.unit == unit);
            let pending = if hit {
                let p = self.prefetch.take().unwrap();
                ctx.charge_wait(p.tok);
                p.gather
            } else {
                ctx.charge_comm("allgather", CommPrim::AllGather, full_bytes);
                None
            };
            self.gather_unit(ctx, sidx, pending)?;
        }
        // issue the next unit's prefetch (layer granularity only): the
        // modeled token and, in real mode, the actual background
        // allgather the comm thread overlaps with this unit's compute
        if self.granularity == Granularity::Layer {
            if let Some(next) = successor(unit, phase, self.layers) {
                let nidx = self.state_idx(next);
                let already = self.states[nidx].resident.is_some()
                    || matches!(&self.prefetch, Some(p) if p.unit == next);
                if !already {
                    let tok = ctx.charge_comm_async_eager(
                        "prefetch-allgather",
                        CommPrim::AllGather,
                        self.states[nidx].layout.full_bytes(),
                    );
                    let gather = self.issue_gather(nidx);
                    if tok.is_some() || gather.is_some() {
                        self.prefetch =
                            Some(Prefetch { unit: next, sidx: nidx, tok, gather });
                    }
                }
            }
        }
        // backward: allocate the full-unit gradient staging buffer
        if phase == Phase::Bwd && self.states[sidx].staging.is_none() {
            let elems = self.states[sidx].layout.padded;
            let tb = ctx.alloc(MemCategory::CommBuf, Buf::Virt(vec![elems]))?;
            self.states[sidx].staging = Some(tb);
            if !self.virt {
                // reuse last step's staging buffer (zero steady-state
                // allocations on the backward staging path)
                let st = &mut self.states[sidx];
                let mut v = st.staged_scratch.take().unwrap_or_default();
                v.clear();
                v.resize(elems, 0.0);
                st.staged_grads = Some(v);
            }
        }
        Ok(())
    }

    fn unit_end(&mut self, ctx: &mut RankCtx, unit: Unit, phase: Phase) -> Result<()> {
        if self.granularity == Granularity::Model {
            // whole-model unit stays resident for the entire step
            return Ok(());
        }
        let sidx = self.state_idx(unit);
        // reshard: free the full weights
        if let Some(tb) = self.states[sidx].resident.take() {
            ctx.free(tb);
        }
        if phase == Phase::Bwd {
            // reduce-scatter the staged grads asynchronously — it overlaps
            // the next unit's backward compute (real FSDP's behavior); the
            // step barrier waits on all of them. Modeled token on the lead
            // rank; the DATA PATH is issued on the background engine here
            // and joined at the barrier.
            if let Some(tok) = ctx.charge_comm_async(
                "reduce-scatter",
                CommPrim::ReduceScatter,
                self.states[sidx].layout.full_bytes(),
            ) {
                self.pending_rs.push(tok);
            }
            if let Some(full) = self.states[sidx].staged_grads.take() {
                let stream = self.coll.as_ref().expect("stream initialized");
                self.pending_rs_data
                    .push((sidx, stream.issue_reduce_scatter(full)));
            }
            if let Some(tb) = self.states[sidx].staging.take() {
                ctx.free(tb);
            }
        }
        Ok(())
    }

    fn params(&self) -> Option<&ModelParams> {
        if self.virt {
            None
        } else {
            Some(&self.scratch)
        }
    }

    fn moe_exchange(&mut self, ctx: &mut RankCtx, bytes: u64) -> Result<()> {
        if ctx.n() > 1 {
            ctx.charge_comm("all-to-all", CommPrim::AllToAll, bytes);
        }
        Ok(())
    }

    fn grad(&mut self, ctx: &mut RankCtx, slot: Slot, src: TBuf) -> Result<()> {
        if !src.is_virtual() {
            // precomputed slot -> (state, spec) index: this hook runs once
            // per parameter per step, so no O(#slots) scan here
            let &(sidx, k) = self.slot_index.get(&slot).expect("slot in unit index");
            let st = &mut self.states[sidx];
            let spec = &st.layout.specs[k];
            if let Some(stage) = st.staged_grads.as_mut() {
                for (d, v) in stage[spec.offset..spec.offset + spec.len()]
                    .iter_mut()
                    .zip(&src.f().data)
                {
                    *d += v;
                }
            }
        }
        ctx.free(src);
        Ok(())
    }
}

/// Re-derive this rank's per-unit param shards from a FULL model — the
/// constructor's sharding math (pack to canonical flat order, keep this
/// rank's padded chunk), shared with the elastic-resume `load_full` path.
/// Grad shards are created zeroed if absent and left untouched otherwise.
fn shard_params_from_full(states: &mut [UnitState], fullp: &mut ModelParams, rank: usize) {
    for st in states.iter_mut() {
        let tensors: Vec<&HostTensor> = st
            .slots
            .iter()
            .map(|&s| &*resolve_mut(fullp, s) as *const HostTensor)
            .collect::<Vec<_>>()
            .into_iter()
            // SAFETY: resolve_mut only borrows disjoint fields; we
            // immediately downgrade to shared refs.
            .map(|p| unsafe { &*p })
            .collect();
        let flat = st.layout.pack(&tensors);
        let shard = st.layout.shard(&flat, rank);
        st.param_shard = Some(HostTensor::from_vec(&[shard.len()], shard));
        if st.grad_shard.is_none() {
            st.grad_shard = Some(HostTensor::zeros(&[st.layout.shard_len()]));
        }
    }
}

/// One FSDP rank: per-unit flat shards + the transient full-unit views.
pub struct FsdpRank {
    rank: usize,
    hooks: FsdpHooks,
    cfg: ModelCfg,
}

impl FsdpRank {
    pub fn new(ctx: &mut RankCtx, seed: u64, granularity: Granularity) -> Result<Self> {
        let n = ctx.n();
        let rank = ctx.rank;
        let cfg = ctx.cfg.clone();
        let virt = ctx.virtual_mode();
        let units = Unit::all(cfg.layers);

        // build unit states (this rank's shards only)
        let mut states = Vec::new();
        match granularity {
            Granularity::Layer => {
                for &u in &units {
                    let (layout, slots) = layout_of(&cfg, u, n);
                    states.push(UnitState {
                        layout,
                        slots,
                        param_shard: None,
                        grad_shard: None,
                        resident: None,
                        staging: None,
                        staged_grads: None,
                        staged_scratch: None,
                        full_scratch: None,
                    });
                }
            }
            Granularity::Model => {
                // single layout concatenating every unit's params
                let mut all: Vec<(Slot, Vec<usize>)> = Vec::new();
                for &u in &units {
                    all.extend(unit_param_list(&cfg, u));
                }
                let named: Vec<(&str, Vec<usize>)> =
                    all.iter().map(|(s, sh)| (s.name, sh.clone())).collect();
                states.push(UnitState {
                    layout: FlatLayout::new(&named, n),
                    slots: all.into_iter().map(|(s, _)| s).collect(),
                    param_shard: None,
                    grad_shard: None,
                    resident: None,
                    staging: None,
                    staged_grads: None,
                    staged_scratch: None,
                    full_scratch: None,
                });
            }
        }

        // initialize this rank's shards from a full seed model (real
        // mode): every rank derives the same full model from the same
        // seed and keeps only its shard — broadcast-at-init without the
        // broadcast.
        if !virt {
            let mut fullp = ModelParams::init(&cfg, &mut Rng::new(seed));
            shard_params_from_full(&mut states, &mut fullp, rank);
        }

        // persistent residency: shard weights + shard grads
        let shard_bytes: u64 = states.iter().map(|s| s.layout.shard_bytes()).sum();
        ctx.tracker.alloc(MemCategory::Weights, shard_bytes)?;
        ctx.tracker.alloc(MemCategory::Grads, shard_bytes)?;

        // slot -> (state, spec) lookup for the per-parameter grad hook
        let mut slot_index = HashMap::new();
        for (sidx, st) in states.iter().enumerate() {
            for (k, slot) in st.slots.iter().enumerate() {
                slot_index.insert(*slot, (sidx, k));
            }
        }

        let scratch = ModelParams::zeros_like(&cfg);
        Ok(FsdpRank {
            rank,
            hooks: FsdpHooks {
                states,
                scratch,
                virt,
                granularity,
                layers: cfg.layers,
                prefetch: None,
                pending_rs: Vec::new(),
                pending_rs_data: Vec::new(),
                slot_index,
                coll: None,
            },
            cfg,
        })
    }

    pub fn granularity(&self) -> Granularity {
        self.hooks.granularity
    }

    /// The step barrier: join the background reduce-scatters issued
    /// during backward (they overlapped the remaining backward compute),
    /// fold each reduced chunk into this rank's grad shard (mean), run
    /// the whole-model unit's reduce-scatter (Model granularity), and
    /// release whole-model residency.
    fn finish_step(&mut self, ctx: &mut RankCtx) -> Result<()> {
        let n = ctx.n();
        let w = self.rank;
        let h = &mut self.hooks;
        // a prefetch issued but never consumed must still be joined so
        // the comm thread and the fabric are quiescent at the barrier
        if let Some(p) = h.prefetch.take() {
            if let Some(g) = p.gather {
                let full = h.coll.as_ref().expect("stream initialized").join(g);
                h.states[p.sidx].full_scratch = Some(full);
            }
        }
        // join the backward reduce-scatters in issue order; each buffer
        // comes back with this rank's reduced chunk in place and retires
        // into the state's staging scratch for next step
        let pending: Vec<(usize, CollHandle)> = h.pending_rs_data.drain(..).collect();
        for (sidx, handle) in pending {
            let full = h.coll.as_ref().expect("stream initialized").join(handle);
            fold_reduced(&mut h.states[sidx], full, w, n);
        }
        for st in h.states.iter_mut() {
            // Model granularity: the whole-model unit was not resharded
            // during the walk — reduce-scatter it blocking at the barrier
            // (still riding the background engine's lanes)
            if let Some(full) = st.staged_grads.take() {
                let stream = h.coll.as_ref().expect("stream initialized");
                let full = stream.join(stream.issue_reduce_scatter(full));
                fold_reduced(st, full, w, n);
            }
            // Model granularity: release residency + staging now
            if let Some(tb) = st.resident.take() {
                ctx.free(tb);
            }
            if let Some(tb) = st.staging.take() {
                ctx.charge_comm(
                    "reduce-scatter",
                    CommPrim::ReduceScatter,
                    st.layout.full_bytes(),
                );
                ctx.free(tb);
            }
        }
        if let Some(tl) = ctx.timeline.as_deref_mut() {
            for tok in h.pending_rs.drain(..) {
                tl.wait(tok);
            }
        }
        h.pending_rs.clear();
        Ok(())
    }
}

impl RankEngine for FsdpRank {
    fn rank(&self) -> usize {
        self.rank
    }

    fn step_local(&mut self, ctx: &mut RankCtx, batch: &Batch) -> Result<f32> {
        let n = ctx.n();
        let shard = batch.shard(self.rank, n);
        let loss = dense_step(ctx, &mut self.hooks, &shard)?;
        self.finish_step(ctx)?;
        if let Some(tl) = ctx.timeline.as_deref_mut() {
            tl.barrier();
        }
        Ok(loss)
    }

    fn gather_params_local(&self, port: &RingPort) -> ModelParams {
        let mut out = ModelParams::zeros_like(&self.cfg);
        for st in &self.hooks.states {
            let shard = st.param_shard.as_ref().expect("virtual mode");
            let full = st.layout.allgather_via(port, &shard.data);
            for (slot, t) in st.slots.iter().zip(st.layout.unpack(&full)) {
                *resolve_mut(&mut out, *slot) = t;
            }
        }
        out
    }

    fn gather_grads_local(&self, port: &RingPort) -> ModelParams {
        let mut out = ModelParams::zeros_like(&self.cfg);
        for st in &self.hooks.states {
            let shard = st.grad_shard.as_ref().expect("virtual mode");
            let full = st.layout.allgather_via(port, &shard.data);
            for (slot, t) in st.slots.iter().zip(st.layout.unpack(&full)) {
                *resolve_mut(&mut out, *slot) = t;
            }
        }
        out
    }

    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor)) {
        for st in &mut self.hooks.states {
            // a unit without shards (virtual mode) skips — it must not
            // abort visiting the remaining units
            let (Some(p), Some(g)) = (st.param_shard.as_mut(), st.grad_shard.as_ref())
            else {
                continue;
            };
            f(p, g);
        }
    }

    fn zero_grads(&mut self) {
        for st in &mut self.hooks.states {
            if let Some(g) = st.grad_shard.as_mut() {
                g.data.fill(0.0);
            }
        }
    }

    fn load_full(&mut self, full: &ModelParams) -> Result<()> {
        if self.hooks.virt {
            anyhow::bail!("load_full: no shards in virtual mode");
        }
        // replay the constructor's sharding math against THIS world size:
        // a checkpoint taken at any N restores into any N' because the
        // flat pad stays zero through training (pad grads are zero, so
        // pad moments are too)
        let mut fullp = full.clone();
        shard_params_from_full(&mut self.hooks.states, &mut fullp, self.rank);
        Ok(())
    }
}
