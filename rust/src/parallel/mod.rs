//! The parallel engines: the paper's RTP (in-place / out-of-place) and
//! every baseline it is evaluated against.
//!
//! | engine        | weights            | activations | reduction            |
//! |---------------|--------------------|-------------|----------------------|
//! | `single`      | full, 1 device     | full        | none (the "idealized computer") |
//! | `ddp`         | full replica × N   | batch shard | grad allreduce       |
//! | `fsdp`        | flat shards        | batch shard | unit allgather + grad reduce-scatter |
//! | `megatron_tp` | static weight shard| FULL batch  | activation allreduce/allgather |
//! | `rtp`         | rotating shard     | batch shard | grads rotate home (no allreduce) |
//!
//! ## SPMD architecture
//!
//! Every engine is N independent [`RankEngine`] participants — one per
//! simulated device — each owning ONLY its rank's state (its shard or
//! replica, its gradients, its memory tracker, its executor, its
//! [`RingPort`](crate::comm::RingPort)). A rank's `step_local` runs the
//! full forward+backward for its batch shard and performs its OWN side of
//! every collective through its port; cross-rank data moves exclusively
//! through the ring fabric. This is the same program shape a real
//! torchrun-style launch has: the paper's §3.4 overlap of per-rank
//! compute with neighbor-only weight rotation is expressible per rank,
//! not just modeled.
//!
//! How the N rank bodies execute is the [`Launcher`]'s choice:
//! - `Launcher::Lockstep` — deterministic single-threaded-equivalent
//!   round-robin (threads as coroutines, one rank at a time, yields only
//!   at empty-mailbox recv). Reproducible traces, exact deadlock
//!   detection. The default.
//! - `Launcher::Thread` — one free-running OS thread per rank over the
//!   `Send` fabric, barrier at step end: real concurrent overlap.
//!
//! Results are bit-identical under both launchers: each directed fabric
//! link is FIFO and each rank's program order is fixed, so reduction
//! order never depends on scheduling.
//!
//! The cluster-level [`Engine`] trait survives as a thin facade
//! ([`ClusterEngine`] = [`Launcher`] + `Vec<Box<dyn RankEngine>>`): the
//! trainer, optimizer, benches and examples keep driving one object.
//!
//! All engines run in real mode (PJRT artifacts or the rust oracle — exact
//! numerics, gradient-equivalence tested) and virtual mode (shape stubs —
//! paper-scale memory/throughput accounting), through the same code.
//!
//! Communication discipline: every inter-worker transfer goes through the
//! rank-local ring fabric — engines never touch another rank's buffers.
//! Collectives are the chunked ring algorithms of [`crate::comm`]
//! (allreduce = 2(N-1) hops, allgather / reduce-scatter = N-1 hops,
//! rotation = 1 hop), charged per hop on the timeline via
//! `RankCtx::charge_comm*` and traced per hop, so every engine's schedule
//! exposes the real hop structure the paper's §3.4 analysis is about. A
//! finished `step` always leaves the fabric drained (asserted).

pub mod bucket;
pub mod builder;
pub mod cluster_engine;
pub mod common;
pub mod ddp;
pub mod dense;
pub mod fsdp;
pub mod launcher;
pub mod rtp;
pub mod single;
pub mod tp;

use anyhow::Result;

pub use builder::{build_engine, EngineOpts, ExecKind};
pub use cluster_engine::ClusterEngine;
pub use common::{Batch, Ctx, RankCtx};
pub use launcher::Launcher;

use crate::model::ModelParams;
use crate::tensor::HostTensor;

/// One rank's participant in a parallel training engine: the SPMD unit.
/// Owns only this rank's model state; all cluster-level resources arrive
/// through the [`RankCtx`] view, and all cross-rank data moves through
/// the fabric port.
pub trait RankEngine: Send + Sync {
    fn rank(&self) -> usize;

    /// One forward+backward pass over this rank's view of the GLOBAL
    /// batch (the engine shards it internally), including this rank's
    /// side of every collective. Returns this rank's mean loss (0.0 in
    /// virtual mode). Grads ACCUMULATE until `zero_grads`. Must be called
    /// from inside a fabric round with every other rank stepping too.
    fn step_local(&mut self, ctx: &mut RankCtx, batch: &Batch) -> Result<f32>;

    /// Reconstruct the FULL model parameters through the fabric (real
    /// mode only — test/checkpoint path). Every rank participates; every
    /// rank returns the same assembled model. Must run inside a fabric
    /// round. Panics in virtual mode.
    fn gather_params_local(&self, port: &crate::comm::RingPort) -> ModelParams;

    /// Reconstruct the full, fully-reduced gradients (real mode only).
    fn gather_grads_local(&self, port: &crate::comm::RingPort) -> ModelParams;

    /// Visit every (param, grad) pair this rank OWNS (its shard layout) —
    /// the optimizer update path. Deterministic order. Real mode only.
    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor));

    fn zero_grads(&mut self);

    /// Replace this rank's OWNED parameter state from a FULL model,
    /// replaying the constructor's sharding math locally — comm-free, so
    /// it needs no fabric round. The elastic-resume path: a checkpoint
    /// taken at any world size restores into an engine at any other.
    /// Real mode only (errors in virtual mode).
    fn load_full(&mut self, full: &ModelParams) -> Result<()>;
}

/// One parallel training engine, cluster view — the facade the trainer,
/// benches and tests drive. Implemented by [`ClusterEngine`] over N
/// [`RankEngine`]s and a [`Launcher`].
pub trait Engine {
    fn name(&self) -> String;

    /// One forward+backward pass over a GLOBAL batch, including the
    /// engine's gradient reduction. Returns the mean loss (0.0 in virtual
    /// mode). Grads ACCUMULATE until `zero_grads`.
    fn step(&mut self, batch: &Batch) -> Result<f32>;

    /// Assemble the full model parameters from the engine's layout
    /// (real mode only — test/checkpoint path).
    fn gather_params(&self) -> ModelParams;

    /// Assemble full, fully-reduced gradients (real mode only).
    fn gather_grads(&self) -> ModelParams;

    /// Visit every (param, grad) pair the engine OWNS (its shard layout) —
    /// the optimizer update path. Deterministic order. Real mode only.
    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor));

    fn zero_grads(&mut self);

    /// Replace the engine's parameter state from a FULL model, re-sharded
    /// to the engine's own layout and world size (real mode only). The
    /// elastic-resume path — see [`RankEngine::load_full`].
    fn load_full(&mut self, full: &ModelParams) -> Result<()>;

    /// Rebase the engine's step counter to GLOBAL coordinates. A cluster
    /// rebuilt mid-run (elastic recovery, `--resume`) starts its internal
    /// counter at 0; rebasing keeps fault-plan step matching and
    /// step-indexed accounting aligned with the run's true step number.
    /// Default: no-op (engines without a step counter).
    fn set_step_base(&mut self, _base: u64) {}

    fn ctx(&self) -> &Ctx;
    fn ctx_mut(&mut self) -> &mut Ctx;
}
