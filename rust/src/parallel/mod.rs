//! The parallel engines: the paper's RTP (in-place / out-of-place) and
//! every baseline it is evaluated against.
//!
//! | engine        | weights            | activations | reduction            |
//! |---------------|--------------------|-------------|----------------------|
//! | `single`      | full, 1 device     | full        | none (the "idealized computer") |
//! | `ddp`         | full replica × N   | batch shard | grad allreduce       |
//! | `fsdp`        | flat shards        | batch shard | unit allgather + grad reduce-scatter |
//! | `megatron_tp` | static weight shard| FULL batch  | activation allreduce/allgather |
//! | `rtp`         | rotating shard     | batch shard | grads rotate home (no allreduce) |
//!
//! All engines run in real mode (PJRT artifacts or the rust oracle — exact
//! numerics, gradient-equivalence tested) and virtual mode (shape stubs —
//! paper-scale memory/throughput accounting), through the same code.
//!
//! Communication discipline: every inter-worker transfer goes through the
//! rank-local ring fabric (`comm::RingPort`) — engines never mutate
//! another rank's buffers directly. Collectives are the chunked ring
//! algorithms of [`crate::comm`] (allreduce = 2(N-1) hops, allgather /
//! reduce-scatter = N-1 hops, rotation = 1 hop), charged per hop on the
//! timeline via `Ctx::charge_comm*` and traced per hop, so every engine's
//! schedule exposes the real hop structure the paper's §3.4 analysis is
//! about. A finished `step` always leaves the fabric drained (asserted).

pub mod builder;
pub mod common;
pub mod ddp;
pub mod dense;
pub mod fsdp;
pub mod rtp;
pub mod single;
pub mod tp;

use anyhow::Result;

pub use builder::{build_engine, EngineOpts, ExecKind};
pub use common::{Batch, Ctx};

use crate::model::ModelParams;
use crate::tensor::HostTensor;

/// One parallel training engine.
pub trait Engine {
    fn name(&self) -> String;

    /// One forward+backward pass over a GLOBAL batch, including the
    /// engine's gradient reduction. Returns the mean loss (0.0 in virtual
    /// mode). Grads ACCUMULATE until `zero_grads`.
    fn step(&mut self, batch: &Batch) -> Result<f32>;

    /// Assemble the full model parameters from the engine's layout
    /// (real mode only — test/checkpoint path).
    fn gather_params(&self) -> ModelParams;

    /// Assemble full, fully-reduced gradients (real mode only).
    fn gather_grads(&self) -> ModelParams;

    /// Visit every (param, grad) pair the engine OWNS (its shard layout) —
    /// the optimizer update path. Deterministic order. Real mode only.
    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor));

    fn zero_grads(&mut self);

    fn ctx(&self) -> &Ctx;
    fn ctx_mut(&mut self) -> &mut Ctx;
}
