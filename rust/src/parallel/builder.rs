//! Engine construction: one entry point that wires config + executor +
//! cluster + timeline into any of the five engines.

use anyhow::{anyhow, Result};

use crate::cluster::Cluster;
use crate::config::{presets, ModelCfg, ParallelCfg, Strategy};
use crate::perfmodel::{Hardware, Timeline};
use crate::runtime::{artifacts_root, Exec, PjrtRuntime};

use super::common::Ctx;
use super::ddp::DdpEngine;
use super::fsdp::{FsdpEngine, Granularity};
use super::rtp::{RtpEngine, RtpVariant};
use super::single::SingleEngine;
use super::tp::TpEngine;
use super::Engine;

/// Which compute backend to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecKind {
    /// AOT HLO artifacts on the PJRT CPU client (the production path).
    Pjrt,
    /// PJRT routed through the Pallas-kernel artifact set where available.
    PjrtPallas,
    /// Pure-rust oracle (artifact-free tests).
    Oracle,
    /// Shape stubs only (paper-scale accounting).
    Virtual,
}

#[derive(Debug, Clone)]
pub struct EngineOpts {
    pub preset: String,
    pub strategy: Strategy,
    pub workers: usize,
    pub global_batch: usize,
    pub exec: ExecKind,
    /// Per-device memory cap (OOM detection); None = unlimited.
    pub capacity: Option<u64>,
    /// Attach a step timeline for this hardware (virtual-mode sweeps).
    pub hardware: Option<Hardware>,
    /// Record the rotation/collective trace.
    pub trace: bool,
    pub seed: u64,
    /// FSDP unit granularity.
    pub fsdp_granularity: Granularity,
    /// RTP out-of-place §3.4.4 buffer recycling.
    pub rtp_recycle: bool,
}

impl EngineOpts {
    pub fn new(preset: &str, strategy: Strategy, workers: usize, global_batch: usize) -> Self {
        EngineOpts {
            preset: preset.to_string(),
            strategy,
            workers,
            global_batch,
            exec: ExecKind::Oracle,
            capacity: None,
            hardware: None,
            trace: false,
            seed: 42,
            fsdp_granularity: Granularity::Layer,
            rtp_recycle: true,
        }
    }

    pub fn exec(mut self, e: ExecKind) -> Self {
        self.exec = e;
        self
    }
    pub fn capacity(mut self, c: Option<u64>) -> Self {
        self.capacity = c;
        self
    }
    pub fn hardware(mut self, hw: Hardware) -> Self {
        self.hardware = Some(hw);
        self
    }
    pub fn trace(mut self, t: bool) -> Self {
        self.trace = t;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
    pub fn fsdp_granularity(mut self, g: Granularity) -> Self {
        self.fsdp_granularity = g;
        self
    }
    pub fn rtp_recycle(mut self, r: bool) -> Self {
        self.rtp_recycle = r;
        self
    }

    pub fn cfg(&self) -> Result<ModelCfg> {
        presets::get(&self.preset)
            .ok_or_else(|| anyhow!("unknown preset {:?}", self.preset))
    }
}

pub fn build_engine(opts: &EngineOpts) -> Result<Box<dyn Engine>> {
    let cfg = opts.cfg()?;
    let workers = if opts.strategy == Strategy::Single { 1 } else { opts.workers };
    let par = ParallelCfg {
        strategy: opts.strategy,
        workers,
        global_batch: opts.global_batch,
    };
    let exec = match opts.exec {
        ExecKind::Oracle => Exec::Oracle,
        ExecKind::Virtual => Exec::Virtual,
        ExecKind::Pjrt => Exec::Pjrt(Box::new(PjrtRuntime::new(
            &artifacts_root(),
            &opts.preset,
        )?)),
        ExecKind::PjrtPallas => Exec::PjrtPallas(Box::new(PjrtRuntime::new(
            &artifacts_root(),
            &opts.preset,
        )?)),
    };
    let mut cluster = Cluster::new(workers, opts.capacity);
    if opts.trace {
        cluster.trace = crate::cluster::TraceLog::enabled();
    }
    let timeline = opts.hardware.clone().map(|hw| Timeline::new(hw, workers));
    let ctx = Ctx { cfg, par, exec, cluster, timeline };

    Ok(match opts.strategy {
        Strategy::Single => Box::new(SingleEngine::new(ctx, opts.seed)?),
        Strategy::Ddp => Box::new(DdpEngine::new(ctx, opts.seed)?),
        Strategy::Fsdp => {
            Box::new(FsdpEngine::new(ctx, opts.seed, opts.fsdp_granularity)?)
        }
        Strategy::MegatronTp => Box::new(TpEngine::new(ctx, opts.seed)?),
        Strategy::RtpInplace => {
            Box::new(RtpEngine::new(ctx, opts.seed, RtpVariant::InPlace)?)
        }
        Strategy::RtpOutOfPlace => Box::new(RtpEngine::new(
            ctx,
            opts.seed,
            RtpVariant::OutOfPlace { recycle: opts.rtp_recycle },
        )?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_every_strategy_virtual() {
        for strategy in Strategy::ALL {
            let opts = EngineOpts::new("tiny", strategy, 4, 4).exec(ExecKind::Virtual);
            let e = build_engine(&opts).unwrap();
            assert!(!e.name().is_empty());
        }
    }

    #[test]
    fn single_forces_one_worker() {
        let opts = EngineOpts::new("tiny", Strategy::Single, 8, 4).exec(ExecKind::Virtual);
        let e = build_engine(&opts).unwrap();
        assert_eq!(e.ctx().cluster.n(), 1);
    }

    #[test]
    fn unknown_preset_is_error() {
        let opts = EngineOpts::new("nope", Strategy::Ddp, 2, 4).exec(ExecKind::Virtual);
        assert!(build_engine(&opts).is_err());
    }

    #[test]
    fn tp_rejects_moe() {
        let opts =
            EngineOpts::new("tiny-moe", Strategy::MegatronTp, 2, 4).exec(ExecKind::Virtual);
        assert!(build_engine(&opts).is_err());
    }
}
