//! Engine construction: one entry point that wires config + executors +
//! cluster + launcher into any of the five engines — N per-rank
//! participants behind one [`ClusterEngine`] facade.

use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::cluster::{Cluster, TraceLog};
use crate::comm::{RingPort, SchedPolicy, TransportKind};
use crate::config::{presets, ModelCfg, ParallelCfg, Strategy};
use crate::memory::tracker::MemTracker;
use crate::perfmodel::{Hardware, Timeline};
use crate::runtime::fault::{FaultInjector, FaultPlan};
use crate::runtime::supervisor::RecoveryPolicy;
use crate::runtime::{artifacts_root, Exec, PjrtRuntime};

use super::cluster_engine::ClusterEngine;
use super::common::{Ctx, RankCtx};
use super::ddp::DdpRank;
use super::fsdp::{FsdpRank, Granularity};
use super::launcher::Launcher;
use super::rtp::{RtpRank, RtpVariant};
use super::single::SingleRank;
use super::tp::TpRank;
use super::{Engine, RankEngine};

/// Which compute backend to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecKind {
    /// AOT HLO artifacts on the PJRT CPU client (the production path).
    Pjrt,
    /// PJRT routed through the Pallas-kernel artifact set where available.
    PjrtPallas,
    /// Pure-rust oracle (artifact-free tests).
    Oracle,
    /// Shape stubs only (paper-scale accounting).
    Virtual,
}

#[derive(Debug, Clone)]
pub struct EngineOpts {
    pub preset: String,
    pub strategy: Strategy,
    pub workers: usize,
    pub global_batch: usize,
    pub exec: ExecKind,
    /// Per-device memory cap (OOM detection); None = unlimited.
    pub capacity: Option<u64>,
    /// Attach a step timeline for this hardware (virtual-mode sweeps).
    pub hardware: Option<Hardware>,
    /// Record the rotation/collective trace.
    pub trace: bool,
    pub seed: u64,
    /// FSDP unit granularity.
    pub fsdp_granularity: Granularity,
    /// RTP out-of-place §3.4.4 buffer recycling.
    pub rtp_recycle: bool,
    /// How the rank bodies execute (defaults to `RTP_LAUNCHER` env).
    pub launcher: Launcher,
    /// Which byte transport carries the fabric's f32 data plane (defaults
    /// to `RTP_TRANSPORT` env; [`TransportKind::Inproc`] when unset).
    /// [`Launcher::Process`] requires a process-capable backend (`shm` or
    /// `uds`).
    pub transport: TransportKind,
    /// TRUE async comm: under the Thread launcher, out-of-place RTP
    /// issues each rotation hop eagerly on the rank's comm stream so the
    /// shard travels while the step computes, and every engine's
    /// [`CollectiveStream`](crate::comm::CollectiveStream) runs its
    /// queued multi-hop collectives (FSDP prefetch allgather + backward
    /// reduce-scatter, DDP/RTP grad allreduce) on a dedicated per-rank
    /// comm thread. Disable to get the synchronous / execute-at-join
    /// baseline the overlap benches compare against. No effect under
    /// Lockstep (always synchronous, for determinism).
    pub async_rotation: bool,
    /// Hop-level scheduling policy for the background collective engine
    /// (defaults to `RTP_SCHED_POLICY` env; [`SchedPolicy::Fifo`] when
    /// unset). Under Lockstep every policy degrades to deterministic
    /// FIFO, so results stay bit-identical across policies.
    pub sched_policy: SchedPolicy,
    /// Size target (bytes) for gradient bucketing in DDP/RTP backward:
    /// the flat grad vector is split into contiguous buckets of roughly
    /// this many bytes and each bucket's allreduce is issued as its own
    /// in-flight collective, giving the hop scheduler several
    /// collectives to interleave. `None` (default, or `RTP_BUCKET_BYTES`
    /// unset/0) keeps today's single monolithic allreduce. NOTE:
    /// bucketing changes ring-chunk boundaries and therefore float
    /// summation order — results are bit-identical across policies and
    /// launchers *given the same bucket size*, but not between bucketed
    /// and monolithic runs.
    pub bucket_bytes: Option<u64>,
    /// Deterministic fault injection: kill `plan.rank` at `plan.step` /
    /// `plan.phase` (defaults to `RTP_FAULT_PLAN` env; `None` = no
    /// injection). A plan whose coordinates never match leaves the run
    /// bit-identical to no plan at all.
    pub fault_plan: Option<FaultPlan>,
    /// Elastic recovery policy for the supervisor (`rtp train --elastic`
    /// / [`Supervisor`](crate::runtime::supervisor::Supervisor)):
    /// shrink-vs-respawn preference, retry budget, backoff schedule.
    /// `None` = the `RTP_RECOVERY` env (or defaults) at supervisor
    /// construction.
    pub recovery: Option<RecoveryPolicy>,
}

/// `RTP_BUCKET_BYTES` env knob: unset, empty or `0` = monolithic.
fn bucket_bytes_from_env() -> Option<u64> {
    match std::env::var("RTP_BUCKET_BYTES") {
        Ok(s) if s.trim().is_empty() => None,
        Ok(s) => match s.trim().parse::<u64>() {
            Ok(0) => None,
            Ok(b) => Some(b),
            Err(_) => panic!("RTP_BUCKET_BYTES={s:?}: expected a byte count"),
        },
        Err(_) => None,
    }
}

impl EngineOpts {
    pub fn new(preset: &str, strategy: Strategy, workers: usize, global_batch: usize) -> Self {
        EngineOpts {
            preset: preset.to_string(),
            strategy,
            workers,
            global_batch,
            exec: ExecKind::Oracle,
            capacity: None,
            hardware: None,
            trace: false,
            seed: 42,
            fsdp_granularity: Granularity::Layer,
            rtp_recycle: true,
            launcher: Launcher::from_env(),
            transport: TransportKind::from_env(),
            async_rotation: true,
            sched_policy: SchedPolicy::from_env(),
            bucket_bytes: bucket_bytes_from_env(),
            fault_plan: FaultPlan::from_env(),
            recovery: None,
        }
    }

    pub fn exec(mut self, e: ExecKind) -> Self {
        self.exec = e;
        self
    }
    pub fn capacity(mut self, c: Option<u64>) -> Self {
        self.capacity = c;
        self
    }
    pub fn hardware(mut self, hw: Hardware) -> Self {
        self.hardware = Some(hw);
        self
    }
    pub fn trace(mut self, t: bool) -> Self {
        self.trace = t;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
    pub fn fsdp_granularity(mut self, g: Granularity) -> Self {
        self.fsdp_granularity = g;
        self
    }
    pub fn rtp_recycle(mut self, r: bool) -> Self {
        self.rtp_recycle = r;
        self
    }
    pub fn launcher(mut self, l: Launcher) -> Self {
        self.launcher = l;
        self
    }
    pub fn transport(mut self, t: TransportKind) -> Self {
        self.transport = t;
        self
    }
    pub fn async_rotation(mut self, a: bool) -> Self {
        self.async_rotation = a;
        self
    }
    pub fn sched_policy(mut self, p: SchedPolicy) -> Self {
        self.sched_policy = p;
        self
    }
    pub fn bucket_bytes(mut self, b: Option<u64>) -> Self {
        self.bucket_bytes = b;
        self
    }
    pub fn fault_plan(mut self, p: Option<FaultPlan>) -> Self {
        self.fault_plan = p;
        self
    }
    pub fn recovery(mut self, r: Option<RecoveryPolicy>) -> Self {
        self.recovery = r;
        self
    }

    pub fn cfg(&self) -> Result<ModelCfg> {
        presets::get(&self.preset)
            .ok_or_else(|| anyhow!("unknown preset {:?}", self.preset))
    }

    pub(crate) fn engine_name(&self) -> String {
        match self.strategy {
            Strategy::Single => "single".to_string(),
            Strategy::Ddp => "ddp".to_string(),
            Strategy::Fsdp => match self.fsdp_granularity {
                Granularity::Layer => "fsdp".to_string(),
                Granularity::Model => "fsdp-model-unit".to_string(),
            },
            Strategy::MegatronTp => "megatron-tp".to_string(),
            Strategy::RtpInplace => "rtp-inplace".to_string(),
            Strategy::RtpOutOfPlace => {
                if self.rtp_recycle {
                    "rtp-outofplace".to_string()
                } else {
                    "rtp-outofplace-norecycle".to_string()
                }
            }
        }
    }
}

pub(crate) fn make_exec(kind: ExecKind, preset: &str) -> Result<Exec> {
    Ok(match kind {
        ExecKind::Oracle => Exec::Oracle,
        ExecKind::Virtual => Exec::Virtual,
        ExecKind::Pjrt => {
            Exec::Pjrt(Box::new(PjrtRuntime::new(&artifacts_root(), preset)?))
        }
        ExecKind::PjrtPallas => {
            Exec::PjrtPallas(Box::new(PjrtRuntime::new(&artifacts_root(), preset)?))
        }
    })
}

/// Construct ONE rank's participant — the per-rank body shared by the
/// in-process facade (below) and the `rtp worker` child process
/// (`runtime::proc::worker_main`), so a process-launched rank is built by
/// exactly the same code path as a thread-launched one.
pub(crate) fn build_rank_engine(
    opts: &EngineOpts,
    cfg: &ModelCfg,
    par: &ParallelCfg,
    rank: usize,
    exec: &mut Exec,
    tracker: &mut MemTracker,
    port: RingPort,
    trace: &Mutex<TraceLog>,
) -> Result<Box<dyn RankEngine>> {
    let mut rctx = RankCtx {
        rank,
        cfg,
        par,
        exec,
        tracker,
        port,
        timeline: None,
        trace_log: trace,
        trace_on: false,
        async_comm: false,
        sched_policy: opts.sched_policy,
        bucket_bytes: opts.bucket_bytes,
        // never inject during construction (step counter is unset
        // there anyway; the facade hands each step's ctxs the live
        // injector)
        fault: None,
    };
    Ok(match opts.strategy {
        Strategy::Single => Box::new(SingleRank::new(&mut rctx, opts.seed)?),
        Strategy::Ddp => Box::new(DdpRank::new(&mut rctx, opts.seed)?),
        Strategy::Fsdp => {
            Box::new(FsdpRank::new(&mut rctx, opts.seed, opts.fsdp_granularity)?)
        }
        Strategy::MegatronTp => Box::new(TpRank::new(&mut rctx, opts.seed)?),
        Strategy::RtpInplace => {
            Box::new(RtpRank::new(&mut rctx, opts.seed, RtpVariant::InPlace)?)
        }
        Strategy::RtpOutOfPlace => Box::new(RtpRank::new(
            &mut rctx,
            opts.seed,
            RtpVariant::OutOfPlace { recycle: opts.rtp_recycle },
        )?),
    })
}

pub fn build_engine(opts: &EngineOpts) -> Result<Box<dyn Engine>> {
    if opts.launcher == Launcher::Process {
        return Ok(Box::new(
            crate::runtime::proc::ProcessClusterEngine::build(opts)?,
        ));
    }
    let cfg = opts.cfg()?;
    let workers = if opts.strategy == Strategy::Single { 1 } else { opts.workers };
    let par = ParallelCfg {
        strategy: opts.strategy,
        workers,
        global_batch: opts.global_batch,
    };
    let mut cluster = Cluster::new_with_transport(workers, opts.capacity, opts.transport);
    if opts.trace {
        cluster.trace = TraceLog::enabled();
    }
    let timeline = opts.hardware.clone().map(|hw| Timeline::new(hw, workers));

    // one executor per simulated device (true SPMD; PJRT loads its
    // artifact set once per rank, exactly as one process per GPU would)
    let mut execs: Vec<Exec> = (0..workers)
        .map(|_| make_exec(opts.exec, &opts.preset))
        .collect::<Result<_>>()?;

    // construct the per-rank participants serially (no comm at init:
    // every rank derives the same full model from the same seed and
    // keeps only its slice)
    let trace = Mutex::new(std::mem::take(&mut cluster.trace));
    let mut ranks: Vec<Box<dyn RankEngine>> = Vec::with_capacity(workers);
    for r in 0..workers {
        let port = cluster.workers[r].port.clone();
        let rank = build_rank_engine(
            opts,
            &cfg,
            &par,
            r,
            &mut execs[r],
            &mut cluster.workers[r].tracker,
            port,
            &trace,
        )?;
        ranks.push(rank);
    }
    cluster.trace = trace.into_inner().unwrap();

    let exec0 = execs.remove(0);
    let ctx = Ctx { cfg, par, exec: exec0, cluster, timeline };
    let fault = opts.fault_plan.map(FaultInjector::new);
    Ok(Box::new(ClusterEngine::new(
        ctx,
        execs,
        ranks,
        opts.launcher,
        opts.async_rotation,
        opts.sched_policy,
        opts.bucket_bytes,
        fault,
        opts.engine_name(),
    )))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_every_strategy_virtual() {
        for strategy in Strategy::ALL {
            let opts = EngineOpts::new("tiny", strategy, 4, 4).exec(ExecKind::Virtual);
            let e = build_engine(&opts).unwrap();
            assert!(!e.name().is_empty());
        }
    }

    #[test]
    fn builds_under_both_launchers() {
        for launcher in [Launcher::Lockstep, Launcher::Thread] {
            let opts = EngineOpts::new("tiny", Strategy::RtpInplace, 2, 4)
                .exec(ExecKind::Virtual)
                .launcher(launcher);
            let e = build_engine(&opts).unwrap();
            assert_eq!(e.name(), "rtp-inplace");
        }
    }

    #[test]
    fn single_forces_one_worker() {
        let opts = EngineOpts::new("tiny", Strategy::Single, 8, 4).exec(ExecKind::Virtual);
        let e = build_engine(&opts).unwrap();
        assert_eq!(e.ctx().cluster.n(), 1);
    }

    #[test]
    fn unknown_preset_is_error() {
        let opts = EngineOpts::new("nope", Strategy::Ddp, 2, 4).exec(ExecKind::Virtual);
        assert!(build_engine(&opts).is_err());
    }

    #[test]
    fn tp_rejects_moe() {
        let opts =
            EngineOpts::new("tiny-moe", Strategy::MegatronTp, 2, 4).exec(ExecKind::Virtual);
        assert!(build_engine(&opts).is_err());
    }
}
