//! Distributed Data Parallel: full replica per worker, batch-sharded
//! activations, bucketed gradient allreduce (paper Table 1 row 3 —
//! (W+G)·(N-1) duplication).
//!
//! Each rank is an independent [`RankEngine`] holding ONE replica and its
//! gradients. The allreduce is issued per layer-bucket DURING the
//! backward walk (PyTorch-DDP style overlap): each `unit_end(Bwd)` fires
//! an async allreduce of that unit's grads on the modeled rank's
//! timeline; `step_local` waits for all of them at the end. Real-mode
//! reduction averages the replicas through the chunked ring allreduce —
//! each rank runs ITS side of the 2(N-1) neighbor hops through its own
//! port — so every replica holds the same mean gradient (allreduce-mean).

use anyhow::Result;

use crate::comm::{CollectiveStream, CommPrim, RingPort};
use crate::memory::tracker::MemCategory;
use crate::model::ModelParams;
use crate::perfmodel::Token;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

use super::bucket::GradBuckets;
use super::common::{Batch, RankCtx, TBuf};
use super::dense::{dense_step, DenseHooks, Phase, Slot, Unit};
use super::single::grad_into;
use super::RankEngine;

/// One DDP rank: a full replica + its local gradient accumulator.
pub struct DdpRank {
    rank: usize,
    hooks: DdpHooks,
    pending: Vec<Token>,
    /// Reused flat-pack scratch for the per-step gradient allreduce.
    flat_scratch: Vec<f32>,
    /// Background collective engine: the full-grad allreduce rides the
    /// per-rank comm thread under the Thread launcher.
    coll: Option<CollectiveStream>,
    /// Persistent per-bucket scratch for the size-targeted bucketed
    /// allreduce (`RankCtx::bucket_elems`; unused when monolithic).
    buckets: GradBuckets,
}

struct DdpHooks {
    /// This rank's full replica (None in virtual mode).
    replica: Option<ModelParams>,
    grads: Option<ModelParams>,
    /// Unit grad bytes (for the per-bucket allreduce charge).
    unit_bytes: Vec<(Unit, u64)>,
    pending: Vec<Token>,
}

impl DenseHooks for DdpHooks {
    fn unit_begin(&mut self, _: &mut RankCtx, _: Unit, _: Phase) -> Result<()> {
        Ok(())
    }

    fn unit_end(&mut self, ctx: &mut RankCtx, unit: Unit, phase: Phase) -> Result<()> {
        // bucketed allreduce overlap: fire this unit's grad reduction as
        // soon as its backward completes (modeled on the lead rank's
        // timeline; charge_comm_async is a no-op elsewhere)
        if phase == Phase::Bwd && ctx.n() > 1 {
            let bytes = self
                .unit_bytes
                .iter()
                .find(|(u, _)| *u == unit)
                .map(|(_, b)| *b)
                .unwrap_or(0);
            if let Some(tok) = ctx.charge_comm_async("allreduce", CommPrim::AllReduce, bytes)
            {
                self.pending.push(tok);
            }
        }
        Ok(())
    }

    fn params(&self) -> Option<&ModelParams> {
        self.replica.as_ref()
    }

    fn grad(&mut self, ctx: &mut RankCtx, slot: Slot, src: TBuf) -> Result<()> {
        if let (Some(g), false) = (self.grads.as_mut(), src.is_virtual()) {
            grad_into(g, slot, &src);
        }
        ctx.free(src);
        Ok(())
    }

    fn moe_exchange(&mut self, ctx: &mut RankCtx, bytes: u64) -> Result<()> {
        // expert-parallel DP shuffles tokens to/from the expert owners
        if ctx.n() > 1 {
            ctx.charge_comm("all-to-all", CommPrim::AllToAll, bytes);
        }
        Ok(())
    }
}

impl DdpRank {
    pub fn new(ctx: &mut RankCtx, seed: u64) -> Result<Self> {
        let virt = ctx.virtual_mode();
        let (replica, grads) = if virt {
            (None, None)
        } else {
            // every replica starts from the SAME seed (DDP broadcast-at-init)
            (
                Some(ModelParams::init(ctx.cfg, &mut Rng::new(seed))),
                Some(ModelParams::zeros_like(ctx.cfg)),
            )
        };
        let wbytes = ctx.cfg.weight_bytes();
        ctx.tracker.alloc(MemCategory::Weights, wbytes)?;
        ctx.tracker.alloc(MemCategory::Grads, wbytes)?;
        let unit_bytes = unit_grad_bytes(ctx.cfg);
        Ok(DdpRank {
            rank: ctx.rank,
            hooks: DdpHooks { replica, grads, unit_bytes, pending: Vec::new() },
            pending: Vec::new(),
            flat_scratch: Vec::new(),
            coll: None,
            buckets: GradBuckets::new(),
        })
    }
}

/// Per-unit parameter bytes (the DDP bucket sizes).
pub fn unit_grad_bytes(cfg: &crate::config::ModelCfg) -> Vec<(Unit, u64)> {
    let h = cfg.hidden;
    let per_layer: usize = 3 * h * h
        + 3 * h
        + h * h
        + h
        + 4 * h
        + if cfg.is_moe() {
            h * cfg.experts + cfg.experts * (2 * h * cfg.expert_ffn + cfg.expert_ffn) + h
        } else {
            2 * h * cfg.ffn + cfg.ffn + h
        };
    let mut v = vec![(Unit::Emb, ((cfg.vocab + cfg.seq) * h * 4) as u64)];
    for l in 0..cfg.layers {
        v.push((Unit::Layer(l), (per_layer * 4) as u64));
    }
    v.push((Unit::Final, ((2 * h + h * cfg.vocab) * 4) as u64));
    v
}

/// Flatten every grad tensor into `buf` (cleared first, capacity reused).
pub(crate) fn pack_params(grads: &ModelParams, buf: &mut Vec<f32>) {
    buf.clear();
    grads.visit(&mut |_, t| buf.extend_from_slice(&t.data));
}

/// Write the reduced flat buffer back into the grad tensors, scaling by
/// `scale` (the 1/N of allreduce-mean).
pub(crate) fn unpack_params_scaled(grads: &mut ModelParams, buf: &[f32], scale: f32) {
    let mut off = 0;
    grads.visit_mut(&mut |_, t| {
        let l = t.data.len();
        t.data.copy_from_slice(&buf[off..off + l]);
        t.scale(scale);
        off += l;
    });
}

impl RankEngine for DdpRank {
    fn rank(&self) -> usize {
        self.rank
    }

    fn step_local(&mut self, ctx: &mut RankCtx, batch: &Batch) -> Result<f32> {
        let n = ctx.n();
        let shard = batch.shard(self.rank, n);
        let loss = dense_step(ctx, &mut self.hooks, &shard)?;
        self.pending.append(&mut self.hooks.pending);

        // real-mode allreduce-mean of every grad tensor across replicas,
        // riding the background collective engine (the comm thread does
        // the ring hops under the Thread launcher; bit-identical values
        // either way — same chunked ring allreduce)
        if !ctx.virtual_mode() && n > 1 {
            if self.coll.is_none() {
                self.coll = Some(ctx.collectives());
            }
            let stream = self.coll.as_ref().unwrap();
            let mut flat = std::mem::take(&mut self.flat_scratch);
            let grads = self.hooks.grads.as_mut().unwrap();
            pack_params(grads, &mut flat);
            match ctx.bucket_elems() {
                // size-targeted buckets: every bucket's allreduce is in
                // flight at once, giving the hop scheduler a set of
                // collectives to interleave
                Some(target) => {
                    self.buckets.allreduce_flat(stream, &mut flat, target);
                }
                None => flat = stream.join(stream.issue_allreduce(flat)),
            }
            unpack_params_scaled(grads, &flat, 1.0 / n as f32);
            self.flat_scratch = flat;
        }
        if let Some(tl) = ctx.timeline.as_deref_mut() {
            for tok in self.pending.drain(..) {
                tl.wait(tok);
            }
            tl.barrier();
        }
        self.pending.clear();
        Ok(loss)
    }

    fn gather_params_local(&self, _port: &RingPort) -> ModelParams {
        // replicas are identical by construction + allreduce-mean
        self.hooks.replica.clone().expect("virtual mode")
    }

    fn gather_grads_local(&self, _port: &RingPort) -> ModelParams {
        self.hooks.grads.clone().expect("virtual mode")
    }

    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor)) {
        if let (Some(p), Some(g)) = (self.hooks.replica.as_mut(), self.hooks.grads.as_ref())
        {
            p.zip_mut(g, &mut |_, t, gt| f(t, gt));
        }
    }

    fn zero_grads(&mut self) {
        if let Some(g) = self.hooks.grads.as_mut() {
            g.visit_mut(&mut |_, t| t.data.fill(0.0));
        }
    }

    fn load_full(&mut self, full: &ModelParams) -> Result<()> {
        let Some(p) = self.hooks.replica.as_mut() else {
            anyhow::bail!("load_full: no replica in virtual mode");
        };
        // DDP init broadcasts one full replica everywhere; resume does too
        *p = full.clone();
        Ok(())
    }
}
