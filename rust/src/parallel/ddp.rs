//! Distributed Data Parallel: full replica per worker, batch-sharded
//! activations, bucketed gradient allreduce (paper Table 1 row 3 —
//! (W+G)·(N-1) duplication).
//!
//! The allreduce is issued per layer-bucket DURING the backward walk
//! (PyTorch-DDP style overlap): each `unit_end(Bwd)` fires an async
//! allreduce of that unit's grads on the timeline; `step` waits for all of
//! them at the end. Real-mode reduction averages the replicas through the
//! chunked ring allreduce on the rank-local fabric — 2(N-1) neighbor hops
//! per bucket, every rank touching only its own port — so every replica
//! holds the same mean gradient (allreduce-mean).

use anyhow::Result;

use crate::comm::{self, CommPrim, RingPort};
use crate::memory::tracker::MemCategory;
use crate::model::ModelParams;
use crate::perfmodel::Token;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

use super::common::{Batch, Ctx, TBuf};
use super::dense::{dense_step, DenseHooks, Phase, Slot, Unit};
use super::single::grad_into;
use super::Engine;

pub struct DdpEngine {
    pub ctx: Ctx,
    hooks: DdpHooks,
    pending: Vec<Token>,
    last_loss: f32,
}

struct DdpHooks {
    /// One full replica per worker (empty in virtual mode).
    replicas: Vec<ModelParams>,
    grads: Vec<ModelParams>,
    /// Which worker the walk is currently running for.
    active: usize,
    /// Unit grad bytes (for the per-bucket allreduce charge).
    unit_bytes: Vec<(Unit, u64)>,
    pending: Vec<Token>,
}

impl DenseHooks for DdpHooks {
    fn unit_begin(&mut self, _: &mut Ctx, _: usize, _: Unit, _: Phase) -> Result<()> {
        Ok(())
    }

    fn unit_end(&mut self, ctx: &mut Ctx, w: usize, unit: Unit, phase: Phase) -> Result<()> {
        // bucketed allreduce overlap: fire this unit's grad reduction as
        // soon as its backward completes (worker 0 = the modeled worker)
        if phase == Phase::Bwd && w == 0 && ctx.n() > 1 {
            let bytes = self
                .unit_bytes
                .iter()
                .find(|(u, _)| *u == unit)
                .map(|(_, b)| *b)
                .unwrap_or(0);
            if let Some(tok) = ctx.charge_comm_async("allreduce", CommPrim::AllReduce, bytes)
            {
                self.pending.push(tok);
            }
        }
        Ok(())
    }

    fn params(&self, w: usize) -> Option<&ModelParams> {
        self.replicas.get(w)
    }

    fn grad(&mut self, ctx: &mut Ctx, w: usize, slot: Slot, src: TBuf) -> Result<()> {
        debug_assert_eq!(w, self.active);
        if let (Some(g), false) = (self.grads.get_mut(w), src.is_virtual()) {
            grad_into(g, slot, &src);
        }
        ctx.free(src);
        Ok(())
    }

    fn moe_exchange(&mut self, ctx: &mut Ctx, w: usize, bytes: u64) -> Result<()> {
        // expert-parallel DP shuffles tokens to/from the expert owners
        if w == 0 && ctx.n() > 1 {
            ctx.charge_comm("all-to-all", CommPrim::AllToAll, bytes);
        }
        Ok(())
    }
}

impl DdpEngine {
    pub fn new(mut ctx: Ctx, seed: u64) -> Result<Self> {
        let n = ctx.n();
        let virt = ctx.virtual_mode();
        let (replicas, grads) = if virt {
            (Vec::new(), Vec::new())
        } else {
            // every replica starts from the SAME seed (DDP broadcast-at-init)
            let reps: Vec<ModelParams> = (0..n)
                .map(|_| ModelParams::init(&ctx.cfg, &mut Rng::new(seed)))
                .collect();
            let grads = (0..n).map(|_| ModelParams::zeros_like(&ctx.cfg)).collect();
            (reps, grads)
        };
        let wbytes = ctx.cfg.weight_bytes();
        for w in 0..n {
            ctx.cluster.tracker(w).alloc(MemCategory::Weights, wbytes)?;
            ctx.cluster.tracker(w).alloc(MemCategory::Grads, wbytes)?;
        }
        let unit_bytes = unit_grad_bytes(&ctx.cfg);
        Ok(DdpEngine {
            ctx,
            hooks: DdpHooks {
                replicas,
                grads,
                active: 0,
                unit_bytes,
                pending: Vec::new(),
            },
            pending: Vec::new(),
            last_loss: 0.0,
        })
    }
}

/// Per-unit parameter bytes (the DDP bucket sizes).
pub fn unit_grad_bytes(cfg: &crate::config::ModelCfg) -> Vec<(Unit, u64)> {
    let h = cfg.hidden;
    let per_layer: usize = 3 * h * h
        + 3 * h
        + h * h
        + h
        + 4 * h
        + if cfg.is_moe() {
            h * cfg.experts + cfg.experts * (2 * h * cfg.expert_ffn + cfg.expert_ffn) + h
        } else {
            2 * h * cfg.ffn + cfg.ffn + h
        };
    let mut v = vec![(Unit::Emb, ((cfg.vocab + cfg.seq) * h * 4) as u64)];
    for l in 0..cfg.layers {
        v.push((Unit::Layer(l), (per_layer * 4) as u64));
    }
    v.push((Unit::Final, ((2 * h + h * cfg.vocab) * 4) as u64));
    v
}

impl Engine for DdpEngine {
    fn name(&self) -> String {
        "ddp".to_string()
    }

    fn step(&mut self, batch: &Batch) -> Result<f32> {
        let n = self.ctx.n();
        if let Some(tl) = self.ctx.timeline.as_mut() {
            tl.reset();
        }
        let mut loss_sum = 0.0;
        for w in 0..n {
            self.hooks.active = w;
            let shard = batch.shard(w, n);
            loss_sum += dense_step(&mut self.ctx, &mut self.hooks, w, &shard)?;
        }
        self.pending.append(&mut self.hooks.pending);

        // real-mode allreduce-mean of every grad tensor across replicas,
        // through each rank's own fabric port
        if !self.ctx.virtual_mode() && n > 1 {
            allreduce_mean_params(self.ctx.ports(), &mut self.hooks.grads);
        }
        if let Some(tl) = self.ctx.timeline.as_mut() {
            for tok in self.pending.drain(..) {
                tl.wait(tok);
            }
            tl.barrier();
        }
        debug_assert_eq!(
            self.ctx.cluster.fabric().in_flight(),
            0,
            "ddp step left ring-fabric messages in flight"
        );
        self.last_loss = loss_sum / n as f32;
        Ok(self.last_loss)
    }

    fn gather_params(&self) -> ModelParams {
        self.hooks.replicas.first().cloned().expect("virtual mode")
    }

    fn gather_grads(&self) -> ModelParams {
        self.hooks.grads.first().cloned().expect("virtual mode")
    }

    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor)) {
        for (p, g) in self.hooks.replicas.iter_mut().zip(&self.hooks.grads) {
            p.zip_mut(g, &mut |_, t, gt| f(t, gt));
        }
    }

    fn zero_grads(&mut self) {
        for g in &mut self.hooks.grads {
            g.visit_mut(&mut |_, t| t.data.fill(0.0));
        }
    }

    fn ctx(&self) -> &Ctx {
        &self.ctx
    }
    fn ctx_mut(&mut self) -> &mut Ctx {
        &mut self.ctx
    }
}

/// Allreduce-mean every parameter across the per-worker grad sets
/// (flat-pack, chunked ring allreduce over the rank-local ports,
/// unpack + 1/N).
pub fn allreduce_mean_params(ports: &[RingPort], grads: &mut [ModelParams]) {
    let n = grads.len();
    if n <= 1 {
        return;
    }
    let mut bufs: Vec<Vec<f32>> = grads
        .iter()
        .map(|g| {
            let mut v = Vec::new();
            g.visit(&mut |_, t| v.extend_from_slice(&t.data));
            v
        })
        .collect();
    comm::allreduce_sum(ports, &mut bufs);
    let scale = 1.0 / n as f32;
    for (g, b) in grads.iter_mut().zip(&bufs) {
        let mut off = 0;
        g.visit_mut(&mut |_, t| {
            let l = t.data.len();
            t.data.copy_from_slice(&b[off..off + l]);
            t.scale(scale);
            off += l;
        });
    }
}
