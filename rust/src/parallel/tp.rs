//! Megatron-style static tensor parallelism (Shoeybi et al. 2019) — the
//! paper's Table-1 row 2: weights shard once and stay put, but the FULL
//! batch's activations are replicated on every worker (`A·(N-1)`
//! duplication), with synchronous activation collectives at the layer
//! boundaries (allreduce for the row-parallel merges, allgather for the
//! output-partition concats).
//!
//! Each rank is an independent [`RankEngine`] holding its static shard.
//! The walk is lockstep: every rank computes each op on the full batch,
//! then runs ITS side of the merge collective through its own port —
//! unlike the batch-sharded engines, ranks here are not independent
//! between collectives.
//!
//! The activation allreduces ride the fabric's pooled `Vec<f32>` lanes
//! (`comm::allreduce_sum` leases its per-hop scratch from the per-link
//! buffer pools), so TP's layer-boundary collectives perform zero
//! steady-state heap allocations in the fabric — the same hot-path
//! contract `tests/fabric_hotpath.rs` asserts for RTP's rotation.

use anyhow::{bail, Result};

use crate::comm::{self, CommPrim, RingPort};
use crate::config::ModelCfg;
use crate::memory::tracker::MemCategory;
use crate::model::ops::Op;
use crate::model::partition::{self, AttnShard, MlpShard};
use crate::model::{MlpParams, ModelParams};
use crate::runtime::fault::FaultPhase;
use crate::runtime::{arg_of, Buf};
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

use super::common::{allgather_tensor, replicated_elems, Batch, RankCtx, RepParams, TBuf};
use super::RankEngine;

/// This rank's static shards of one layer.
struct LayerShard {
    attn: AttnShard,
    mlp: MlpShard,
}

/// This rank's slice of the model (real mode only).
struct TpState {
    wte: HostTensor,
    wpe: HostTensor,
    layers: Vec<LayerShard>,
    lm: HostTensor, // wlm column shard
    rep: RepParams,
    // gradients, same layout
    g_wte: HostTensor,
    g_wpe: HostTensor,
    g_layers: Vec<LayerShard>,
    g_lm: HostTensor,
    g_rep: RepParams,
}

pub struct TpRank {
    rank: usize,
    n: usize,
    cfg: ModelCfg,
    state: Option<TpState>, // None in virtual mode
}

/// Sum this rank's partial activation buffer with every peer's (the
/// Megatron g-operator): charge the 2(N-1)-hop ring allreduce and, in
/// real mode, move the data through this rank's own fabric port.
fn allreduce_partial(ctx: &mut RankCtx, buf: &mut TBuf) {
    ctx.charge_comm("ar-act", CommPrim::AllReduce, buf.buf.bytes());
    if buf.is_virtual() || ctx.n() <= 1 {
        return;
    }
    let mut flat = std::mem::take(&mut buf.f_mut().data);
    comm::allreduce_sum(&ctx.port, &mut flat);
    buf.f_mut().data = flat;
}

impl TpRank {
    pub fn new(ctx: &mut RankCtx, seed: u64) -> Result<Self> {
        if ctx.cfg.is_moe() {
            bail!("megatron-tp engine does not support MoE models (the paper evaluates MoE on DP/FSDP/RTP only)");
        }
        let n = ctx.n();
        let rank = ctx.rank;
        let cfg = ctx.cfg.clone();
        let virt = ctx.virtual_mode();

        let state = if virt {
            None
        } else {
            let full = ModelParams::init(&cfg, &mut Rng::new(seed));
            let heads = cfg.heads;
            let hd = cfg.head_dim();
            let layers: Vec<LayerShard> = full
                .layers
                .iter()
                .map(|lp| {
                    let (w1, b1, w2) = match &lp.mlp {
                        MlpParams::Dense { w1, b1, w2, .. } => (w1, b1, w2),
                        _ => unreachable!(),
                    };
                    LayerShard {
                        attn: partition::attn_shard(
                            &lp.wqkv, &lp.bqkv, &lp.wo, rank, n, heads, hd,
                        ),
                        mlp: partition::mlp_shard(w1, b1, w2, rank, n),
                    }
                })
                .collect();
            let zero = |t: &HostTensor| HostTensor::zeros(&t.shape);
            let wte = partition::shard_cols(&full.wte, rank, n);
            let wpe = partition::shard_cols(&full.wpe, rank, n);
            let lm = partition::shard_cols(&full.wlm, rank, n);
            let rep = RepParams::from_full(&full);
            Some(TpState {
                g_wte: zero(&wte),
                g_wpe: zero(&wpe),
                g_layers: layers
                    .iter()
                    .map(|l| LayerShard {
                        attn: AttnShard {
                            wqkv: zero(&l.attn.wqkv),
                            bqkv: zero(&l.attn.bqkv),
                            wo: zero(&l.attn.wo),
                        },
                        mlp: MlpShard {
                            w1: zero(&l.mlp.w1),
                            b1: zero(&l.mlp.b1),
                            w2: zero(&l.mlp.w2),
                        },
                    })
                    .collect(),
                g_lm: zero(&lm),
                g_rep: rep.zeros_like(),
                wte,
                wpe,
                layers,
                lm,
                rep,
            })
        };

        // persistent residency: weight shard + grad shard + replicated×2
        let sharded = (cfg.params_total() - replicated_elems(&cfg)) / n;
        let per_worker = ((sharded + replicated_elems(&cfg)) * 4) as u64;
        ctx.tracker.alloc(MemCategory::Weights, per_worker)?;
        ctx.tracker.alloc(MemCategory::Grads, per_worker)?;
        Ok(TpRank { rank, n, cfg, state })
    }

    /// Clone a replicated tensor out of the state so the borrow on
    /// `self` ends before `ctx` is mutably borrowed by `call_op`.
    /// These are tiny ([H]-sized) tensors; the clone is negligible.
    fn rep_tensor(&self, get: impl Fn(&RepParams) -> &HostTensor) -> Option<HostTensor> {
        self.state.as_ref().map(|s| get(&s.rep).clone())
    }
}

impl RankEngine for TpRank {
    fn rank(&self) -> usize {
        self.rank
    }

    fn step_local(&mut self, ctx: &mut RankCtx, batch: &Batch) -> Result<f32> {
        let n = ctx.n();
        let cfg = self.cfg.clone();
        let b = batch.ids.shape[0]; // FULL batch on every rank
        let (h, v) = (cfg.hidden, cfg.vocab);
        let (hp, vp) = (h / n, v / n);
        let virt = ctx.virtual_mode();
        let acts = MemCategory::Activations;
        let w = self.rank;

        // replicated inputs
        let mk = |t: &crate::tensor::IntTensor| {
            if virt { Buf::Virt(vec![b, cfg.seq]) } else { Buf::Ids(t.clone()) }
        };
        let ids = ctx.alloc(acts, mk(&batch.ids))?;
        let tgts = ctx.alloc(acts, mk(&batch.targets))?;

        // ---------------- forward ----------------
        ctx.fault_point(FaultPhase::Forward);
        // embedding: compute my hidden slice, allgather the full hidden
        let mut x = ctx.alloc(acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?;
        {
            let (wte, wpe) = match &self.state {
                Some(s) => (Some(&s.wte), Some(&s.wpe)),
                None => (None, None),
            };
            let mut outs = ctx.call_op(
                Op::EmbFwd,
                b,
                n,
                &[ids.buf.arg(), arg_of(wte), arg_of(wpe)],
                &[acts],
            )?;
            let part = outs.pop().unwrap();
            ctx.charge_comm("ag-emb", CommPrim::AllGather, x.buf.bytes());
            if !virt {
                let pieces = allgather_tensor(&ctx.port, part.f());
                if let Buf::Real(full) = &mut x.buf {
                    for (s, piece) in pieces.into_iter().enumerate() {
                        full.write_slice_last(s * hp, &piece);
                    }
                }
            }
            ctx.free(part);
        }

        struct SavedTp {
            x_in: TBuf,
            a: TBuf,
            x_mid: TBuf,
            m: TBuf,
        }
        let mut saved: Vec<SavedTp> = Vec::new();

        for l in 0..cfg.layers {
            // ln1 (replicated)
            let a = {
                let g = self.rep_tensor(|r| &r.layers[l].ln1_g);
                let bb = self.rep_tensor(|r| &r.layers[l].ln1_b);
                let mut outs = ctx.call_op(
                    Op::LnFwd,
                    b,
                    n,
                    &[x.buf.arg(), arg_of(g.as_ref()), arg_of(bb.as_ref())],
                    &[acts],
                )?;
                outs.pop().unwrap()
            };
            // attention partial + allreduce
            let mut part = {
                let sh = self.state.as_ref().map(|s| &s.layers[l].attn);
                let mut outs = ctx.call_op(
                    Op::AttnFwd,
                    b,
                    n,
                    &[
                        a.buf.arg(),
                        arg_of(sh.map(|s| &s.wqkv)),
                        arg_of(sh.map(|s| &s.bqkv)),
                        arg_of(sh.map(|s| &s.wo)),
                    ],
                    &[acts],
                )?;
                outs.pop().unwrap()
            };
            allreduce_partial(ctx, &mut part);
            let bo = self.rep_tensor(|r| &r.layers[l].bo);
            ctx.add_bias(&mut part, bo.as_ref());
            ctx.residual(&mut part, &x);
            let x_mid = part;
            // ln2 + mlp partial + allreduce
            let m = {
                let g = self.rep_tensor(|r| &r.layers[l].ln2_g);
                let bb = self.rep_tensor(|r| &r.layers[l].ln2_b);
                let mut outs = ctx.call_op(
                    Op::LnFwd,
                    b,
                    n,
                    &[x_mid.buf.arg(), arg_of(g.as_ref()), arg_of(bb.as_ref())],
                    &[acts],
                )?;
                outs.pop().unwrap()
            };
            let mut part = {
                let sh = self.state.as_ref().map(|s| &s.layers[l].mlp);
                let mut outs = ctx.call_op(
                    Op::MlpFwd,
                    b,
                    n,
                    &[
                        m.buf.arg(),
                        arg_of(sh.map(|s| &s.w1)),
                        arg_of(sh.map(|s| &s.b1)),
                        arg_of(sh.map(|s| &s.w2)),
                    ],
                    &[acts],
                )?;
                outs.pop().unwrap()
            };
            allreduce_partial(ctx, &mut part);
            let b2 = self.rep_tensor(|r| &r.layers[l].b2);
            ctx.add_bias(&mut part, b2.as_ref());
            ctx.residual(&mut part, &x_mid);
            saved.push(SavedTp { x_in: x, a, x_mid, m });
            x = part;
        }

        // final LN + LM head (allgather logits) + loss
        let xf = {
            let g = self.rep_tensor(|r| &r.lnf_g);
            let bb = self.rep_tensor(|r| &r.lnf_b);
            let mut outs = ctx.call_op(
                Op::LnFwd,
                b,
                n,
                &[x.buf.arg(), arg_of(g.as_ref()), arg_of(bb.as_ref())],
                &[acts],
            )?;
            outs.pop().unwrap()
        };
        let logit_part = {
            let wlm = self.state.as_ref().map(|s| &s.lm);
            let mut outs = ctx.call_op(
                Op::LmheadFwd,
                b,
                n,
                &[xf.buf.arg(), arg_of(wlm)],
                &[acts],
            )?;
            outs.pop().unwrap()
        };
        let mut logits = ctx.alloc(acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, v]))?;
        ctx.charge_comm("ag-logits", CommPrim::AllGather, logits.buf.bytes());
        if !virt {
            let pieces = allgather_tensor(&ctx.port, logit_part.f());
            if let Buf::Real(full) = &mut logits.buf {
                for (s, piece) in pieces.into_iter().enumerate() {
                    full.write_slice_last(s * vp, &piece);
                }
            }
        }
        ctx.free(logit_part);

        let mut outs = ctx.call_op(
            Op::Xent,
            b,
            n,
            &[logits.buf.arg(), tgts.buf.arg()],
            &[acts, acts],
        )?;
        let dlogits = outs.pop().unwrap();
        let lbuf = outs.pop().unwrap();
        let loss = ctx.loss_of(&lbuf);
        ctx.free(lbuf);
        ctx.free(logits);
        ctx.free(tgts);

        // ---------------- backward ----------------
        ctx.fault_point(FaultPhase::Backward);
        // LM head: my vocab slice of dlogits -> dx partial
        let mut dxf = {
            let dl_w = ctx.col_slice(&dlogits, w * vp, vp, acts)?;
            let wlm = self.state.as_ref().map(|s| &s.lm);
            let mut outs = ctx.call_op(
                Op::LmheadBwd,
                b,
                n,
                &[xf.buf.arg(), arg_of(wlm), dl_w.buf.arg()],
                &[acts, MemCategory::Grads],
            )?;
            let dwlm = outs.pop().unwrap();
            let dx = outs.pop().unwrap();
            if let Some(st) = self.state.as_mut() {
                st.g_lm.add_assign(dwlm.f());
            }
            ctx.free(dwlm);
            ctx.free(dl_w);
            dx
        };
        ctx.free(dlogits);
        allreduce_partial(ctx, &mut dxf);

        // final LN backward (replicated grads, no comm)
        let mut dx = {
            let g = self.rep_tensor(|r| &r.lnf_g);
            let mut outs = ctx.call_op(
                Op::LnBwd,
                b,
                n,
                &[x.buf.arg(), arg_of(g.as_ref()), dxf.buf.arg()],
                &[acts, MemCategory::Grads, MemCategory::Grads],
            )?;
            let db = outs.pop().unwrap();
            let dg = outs.pop().unwrap();
            let d = outs.pop().unwrap();
            if let Some(st) = self.state.as_mut() {
                st.g_rep.lnf_g.add_assign(dg.f());
                st.g_rep.lnf_b.add_assign(db.f());
            }
            ctx.free(db);
            ctx.free(dg);
            d
        };
        ctx.free(dxf);
        ctx.free(xf);
        ctx.free(x);

        for l in (0..cfg.layers).rev() {
            let SavedTp { x_in, a, x_mid, m } = saved.pop().unwrap();
            // b2 grads (replicated)
            if let Some(st) = self.state.as_mut() {
                st.g_rep.layers[l].b2.add_assign(&dx.f().sum_leading());
            }
            // mlp backward -> dm partial (allreduce)
            let mut dm = {
                let sh = self.state.as_ref().map(|s| &s.layers[l].mlp);
                let mut outs = ctx.call_op(
                    Op::MlpBwd,
                    b,
                    n,
                    &[
                        m.buf.arg(),
                        arg_of(sh.map(|s| &s.w1)),
                        arg_of(sh.map(|s| &s.b1)),
                        arg_of(sh.map(|s| &s.w2)),
                        dx.buf.arg(),
                    ],
                    &[acts, MemCategory::Grads, MemCategory::Grads, MemCategory::Grads],
                )?;
                let dw2 = outs.pop().unwrap();
                let db1 = outs.pop().unwrap();
                let dw1 = outs.pop().unwrap();
                let d = outs.pop().unwrap();
                if let Some(st) = self.state.as_mut() {
                    let g = &mut st.g_layers[l].mlp;
                    g.w2.add_assign(dw2.f());
                    g.b1.add_assign(db1.f());
                    g.w1.add_assign(dw1.f());
                }
                ctx.free(dw2);
                ctx.free(db1);
                ctx.free(dw1);
                d
            };
            allreduce_partial(ctx, &mut dm);
            // ln2 backward + residual accumulate
            {
                let g = self.rep_tensor(|r| &r.layers[l].ln2_g);
                let mut outs = ctx.call_op(
                    Op::LnBwd,
                    b,
                    n,
                    &[x_mid.buf.arg(), arg_of(g.as_ref()), dm.buf.arg()],
                    &[acts, MemCategory::Grads, MemCategory::Grads],
                )?;
                let db = outs.pop().unwrap();
                let dg = outs.pop().unwrap();
                let dxl = outs.pop().unwrap();
                if let Some(st) = self.state.as_mut() {
                    st.g_rep.layers[l].ln2_g.add_assign(dg.f());
                    st.g_rep.layers[l].ln2_b.add_assign(db.f());
                }
                ctx.free(db);
                ctx.free(dg);
                ctx.accumulate(&mut dx, &dxl);
                ctx.free(dxl);
            }
            ctx.free(dm);
            ctx.free(m);
            ctx.free(x_mid);
            // bo grads + attention backward
            if let Some(st) = self.state.as_mut() {
                st.g_rep.layers[l].bo.add_assign(&dx.f().sum_leading());
            }
            let mut da = {
                let sh = self.state.as_ref().map(|s| &s.layers[l].attn);
                let mut outs = ctx.call_op(
                    Op::AttnBwd,
                    b,
                    n,
                    &[
                        a.buf.arg(),
                        arg_of(sh.map(|s| &s.wqkv)),
                        arg_of(sh.map(|s| &s.bqkv)),
                        arg_of(sh.map(|s| &s.wo)),
                        dx.buf.arg(),
                    ],
                    &[acts, MemCategory::Grads, MemCategory::Grads, MemCategory::Grads],
                )?;
                let dwo = outs.pop().unwrap();
                let dbq = outs.pop().unwrap();
                let dwq = outs.pop().unwrap();
                let d = outs.pop().unwrap();
                if let Some(st) = self.state.as_mut() {
                    let g = &mut st.g_layers[l].attn;
                    g.wo.add_assign(dwo.f());
                    g.bqkv.add_assign(dbq.f());
                    g.wqkv.add_assign(dwq.f());
                }
                ctx.free(dwo);
                ctx.free(dbq);
                ctx.free(dwq);
                d
            };
            allreduce_partial(ctx, &mut da);
            {
                let g = self.rep_tensor(|r| &r.layers[l].ln1_g);
                let mut outs = ctx.call_op(
                    Op::LnBwd,
                    b,
                    n,
                    &[x_in.buf.arg(), arg_of(g.as_ref()), da.buf.arg()],
                    &[acts, MemCategory::Grads, MemCategory::Grads],
                )?;
                let db = outs.pop().unwrap();
                let dg = outs.pop().unwrap();
                let dxl = outs.pop().unwrap();
                if let Some(st) = self.state.as_mut() {
                    st.g_rep.layers[l].ln1_g.add_assign(dg.f());
                    st.g_rep.layers[l].ln1_b.add_assign(db.f());
                }
                ctx.free(db);
                ctx.free(dg);
                ctx.accumulate(&mut dx, &dxl);
                ctx.free(dxl);
            }
            ctx.free(da);
            ctx.free(a);
            ctx.free(x_in);
        }

        // embedding backward: my hidden slice
        {
            let dx_w = ctx.col_slice(&dx, w * hp, hp, acts)?;
            let mut outs = ctx.call_op(
                Op::EmbBwd,
                b,
                n,
                &[ids.buf.arg(), dx_w.buf.arg()],
                &[MemCategory::Grads, MemCategory::Grads],
            )?;
            let dwpe = outs.pop().unwrap();
            let dwte = outs.pop().unwrap();
            if let Some(st) = self.state.as_mut() {
                st.g_wte.add_assign(dwte.f());
                st.g_wpe.add_assign(dwpe.f());
            }
            ctx.free(dwte);
            ctx.free(dwpe);
            ctx.free(dx_w);
        }
        ctx.free(dx);
        ctx.free(ids);
        if let Some(tl) = ctx.timeline.as_deref_mut() {
            tl.barrier();
        }
        Ok(loss)
    }

    fn gather_params_local(&self, port: &RingPort) -> ModelParams {
        let st = self.state.as_ref().expect("virtual mode");
        assemble(
            &self.cfg,
            port,
            &st.wte,
            &st.wpe,
            &st.layers,
            &st.lm,
            &st.rep,
        )
    }

    fn gather_grads_local(&self, port: &RingPort) -> ModelParams {
        let st = self.state.as_ref().expect("virtual mode");
        assemble(
            &self.cfg,
            port,
            &st.g_wte,
            &st.g_wpe,
            &st.g_layers,
            &st.g_lm,
            &st.g_rep,
        )
    }

    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor)) {
        let Some(st) = self.state.as_mut() else { return };
        f(&mut st.wte, &st.g_wte);
        f(&mut st.wpe, &st.g_wpe);
        for (pl, gl) in st.layers.iter_mut().zip(&st.g_layers) {
            f(&mut pl.attn.wqkv, &gl.attn.wqkv);
            f(&mut pl.attn.bqkv, &gl.attn.bqkv);
            f(&mut pl.attn.wo, &gl.attn.wo);
            f(&mut pl.mlp.w1, &gl.mlp.w1);
            f(&mut pl.mlp.b1, &gl.mlp.b1);
            f(&mut pl.mlp.w2, &gl.mlp.w2);
        }
        f(&mut st.lm, &st.g_lm);
        {
            let mut gs: Vec<*const HostTensor> = Vec::new();
            st.g_rep.visit(&mut |t| gs.push(t));
            let mut i = 0;
            st.rep.visit_mut(&mut |t| {
                // SAFETY: parallel traversal of structurally-equal trees
                f(t, unsafe { &*gs[i] });
                i += 1;
            });
        }
    }

    fn zero_grads(&mut self) {
        let Some(st) = self.state.as_mut() else { return };
        st.g_wte.data.fill(0.0);
        st.g_wpe.data.fill(0.0);
        for gl in &mut st.g_layers {
            gl.attn.wqkv.data.fill(0.0);
            gl.attn.bqkv.data.fill(0.0);
            gl.attn.wo.data.fill(0.0);
            gl.mlp.w1.data.fill(0.0);
            gl.mlp.b1.data.fill(0.0);
            gl.mlp.w2.data.fill(0.0);
        }
        st.g_lm.data.fill(0.0);
        st.g_rep.visit_mut(&mut |t| t.data.fill(0.0));
    }

    fn load_full(&mut self, full: &ModelParams) -> Result<()> {
        let (rank, n) = (self.rank, self.n);
        let heads = self.cfg.heads;
        let hd = self.cfg.head_dim();
        let Some(st) = self.state.as_mut() else {
            bail!("load_full: no shards in virtual mode");
        };
        // replay the constructor's static partitioning against THIS
        // rank/world size (grad shards keep their shapes: same n)
        st.wte = partition::shard_cols(&full.wte, rank, n);
        st.wpe = partition::shard_cols(&full.wpe, rank, n);
        st.lm = partition::shard_cols(&full.wlm, rank, n);
        st.layers = full
            .layers
            .iter()
            .map(|lp| {
                let (w1, b1, w2) = match &lp.mlp {
                    MlpParams::Dense { w1, b1, w2, .. } => (w1, b1, w2),
                    _ => unreachable!(),
                };
                LayerShard {
                    attn: partition::attn_shard(
                        &lp.wqkv, &lp.bqkv, &lp.wo, rank, n, heads, hd,
                    ),
                    mlp: partition::mlp_shard(w1, b1, w2, rank, n),
                }
            })
            .collect();
        st.rep = RepParams::from_full(full);
        Ok(())
    }
}

/// Reconstruct the full model from this rank's shards by ring-allgathering
/// every sharded tensor through `port` (all ranks must call in step).
fn assemble(
    cfg: &ModelCfg,
    port: &RingPort,
    wte: &HostTensor,
    wpe: &HostTensor,
    layers: &[LayerShard],
    lm: &HostTensor,
    rep: &RepParams,
) -> ModelParams {
    let heads = cfg.heads;
    let hd = cfg.head_dim();
    let mut out = ModelParams::zeros_like(cfg);
    out.wte = partition::unshard_cols(&allgather_tensor(port, wte));
    out.wpe = partition::unshard_cols(&allgather_tensor(port, wpe));
    for (l, lp) in out.layers.iter_mut().enumerate() {
        let sh = &layers[l];
        lp.wqkv = partition::unshard_qkv_cols(
            &allgather_tensor(port, &sh.attn.wqkv),
            heads,
            hd,
        );
        lp.bqkv = partition::unshard_qkv_cols(
            &allgather_tensor(port, &sh.attn.bqkv),
            heads,
            hd,
        );
        lp.wo = partition::unshard_rows(&allgather_tensor(port, &sh.attn.wo));
        let rl = &rep.layers[l];
        lp.ln1_g = rl.ln1_g.clone();
        lp.ln1_b = rl.ln1_b.clone();
        lp.bo = rl.bo.clone();
        lp.ln2_g = rl.ln2_g.clone();
        lp.ln2_b = rl.ln2_b.clone();
        lp.mlp = MlpParams::Dense {
            w1: partition::unshard_cols(&allgather_tensor(port, &sh.mlp.w1)),
            b1: partition::unshard_cols(&allgather_tensor(port, &sh.mlp.b1)),
            w2: partition::unshard_rows(&allgather_tensor(port, &sh.mlp.w2)),
            b2: rl.b2.clone(),
        };
    }
    out.lnf_g = rep.lnf_g.clone();
    out.lnf_b = rep.lnf_b.clone();
    out.wlm = partition::unshard_cols(&allgather_tensor(port, lm));
    out
}
