//! Megatron-style static tensor parallelism (Shoeybi et al. 2019) — the
//! paper's Table-1 row 2: weights shard once and stay put, but the FULL
//! batch's activations are replicated on every worker (`A·(N-1)`
//! duplication), with synchronous activation collectives at the layer
//! boundaries (allreduce for the row-parallel merges, allgather for the
//! output-partition concats).
//!
//! The walk is lockstep: every worker computes each op on the full batch
//! before the merge collective runs — unlike the batch-sharded engines,
//! workers here are not independent between collectives.

use anyhow::{bail, Result};

use crate::comm::{self, CommPrim};
use crate::memory::tracker::MemCategory;
use crate::model::ops::Op;
use crate::model::partition::{self, AttnShard, MlpShard};
use crate::model::{MlpParams, ModelParams};
use crate::runtime::{arg_of, Buf};
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

use super::common::{replicated_elems, Batch, Ctx, RepParams, TBuf};
use super::Engine;

/// Static per-worker shards of one layer.
struct LayerShards {
    attn: Vec<AttnShard>,
    mlp: Vec<MlpShard>,
}

struct TpState {
    emb: Vec<(HostTensor, HostTensor)>, // (wte_s, wpe_s) per worker
    layers: Vec<LayerShards>,
    lm: Vec<HostTensor>, // wlm column shard per worker
    rep: Vec<RepParams>,
    // gradients, same layout
    g_emb: Vec<(HostTensor, HostTensor)>,
    g_layers: Vec<LayerShards>,
    g_lm: Vec<HostTensor>,
    g_rep: Vec<RepParams>,
}

pub struct TpEngine {
    pub ctx: Ctx,
    state: Option<TpState>, // None in virtual mode
    last_loss: f32,
}

/// Sum per-worker partial activation buffers (the Megatron g-operator):
/// charge the 2(N-1)-hop ring allreduce and, in real mode, move the data
/// through each rank's own fabric port.
fn allreduce_partials(ctx: &mut Ctx, bufs: &mut [TBuf]) {
    ctx.charge_comm("ar-act", CommPrim::AllReduce, bufs[0].buf.bytes());
    if bufs[0].is_virtual() || bufs.len() <= 1 {
        return;
    }
    let ports = ctx.ports();
    let mut flats: Vec<Vec<f32>> = bufs.iter().map(|b| b.f().data.clone()).collect();
    comm::allreduce_sum(ports, &mut flats);
    for (b, f) in bufs.iter_mut().zip(flats) {
        b.f_mut().data = f;
    }
}

impl TpEngine {
    pub fn new(mut ctx: Ctx, seed: u64) -> Result<Self> {
        if ctx.cfg.is_moe() {
            bail!("megatron-tp engine does not support MoE models (the paper evaluates MoE on DP/FSDP/RTP only)");
        }
        let n = ctx.n();
        let cfg = ctx.cfg.clone();
        let virt = ctx.virtual_mode();

        let state = if virt {
            None
        } else {
            let full = ModelParams::init(&cfg, &mut Rng::new(seed));
            let heads = cfg.heads;
            let hd = cfg.head_dim();
            let emb: Vec<(HostTensor, HostTensor)> = (0..n)
                .map(|s| {
                    (
                        partition::shard_cols(&full.wte, s, n),
                        partition::shard_cols(&full.wpe, s, n),
                    )
                })
                .collect();
            let layers: Vec<LayerShards> = full
                .layers
                .iter()
                .map(|lp| {
                    let (w1, b1, w2) = match &lp.mlp {
                        MlpParams::Dense { w1, b1, w2, .. } => (w1, b1, w2),
                        _ => unreachable!(),
                    };
                    LayerShards {
                        attn: (0..n)
                            .map(|s| {
                                partition::attn_shard(&lp.wqkv, &lp.bqkv, &lp.wo, s, n, heads, hd)
                            })
                            .collect(),
                        mlp: (0..n).map(|s| partition::mlp_shard(w1, b1, w2, s, n)).collect(),
                    }
                })
                .collect();
            let lm: Vec<HostTensor> =
                (0..n).map(|s| partition::shard_cols(&full.wlm, s, n)).collect();
            let rep = vec![RepParams::from_full(&full); n];
            let zero = |t: &HostTensor| HostTensor::zeros(&t.shape);
            Some(TpState {
                g_emb: emb.iter().map(|(a, b)| (zero(a), zero(b))).collect(),
                g_layers: layers
                    .iter()
                    .map(|l| LayerShards {
                        attn: l
                            .attn
                            .iter()
                            .map(|a| AttnShard {
                                wqkv: zero(&a.wqkv),
                                bqkv: zero(&a.bqkv),
                                wo: zero(&a.wo),
                            })
                            .collect(),
                        mlp: l
                            .mlp
                            .iter()
                            .map(|m| MlpShard {
                                w1: zero(&m.w1),
                                b1: zero(&m.b1),
                                w2: zero(&m.w2),
                            })
                            .collect(),
                    })
                    .collect(),
                g_lm: lm.iter().map(zero).collect(),
                g_rep: rep.iter().map(|r| r.zeros_like()).collect(),
                emb,
                layers,
                lm,
                rep,
            })
        };

        // persistent residency: weight shard + grad shard + replicated×2
        let sharded = (cfg.params_total() - replicated_elems(&cfg)) / n;
        let per_worker = ((sharded + replicated_elems(&cfg)) * 4) as u64;
        for w in 0..n {
            ctx.cluster.tracker(w).alloc(MemCategory::Weights, per_worker)?;
            ctx.cluster.tracker(w).alloc(MemCategory::Grads, per_worker)?;
        }
        Ok(TpEngine { ctx, state, last_loss: 0.0 })
    }

    /// Clone a replicated tensor out of the state so the borrow on
    /// `self` ends before `self.ctx` is mutably borrowed by `call_op`.
    /// These are tiny ([H]-sized) tensors; the clone is negligible.
    fn rep_tensor(&self, w: usize, get: impl Fn(&RepParams) -> &HostTensor)
        -> Option<HostTensor>
    {
        self.state.as_ref().map(|s| get(&s.rep[w]).clone())
    }
}

impl Engine for TpEngine {
    fn name(&self) -> String {
        "megatron-tp".to_string()
    }

    fn step(&mut self, batch: &Batch) -> Result<f32> {
        let n = self.ctx.n();
        let cfg = self.ctx.cfg.clone();
        let b = batch.ids.shape[0]; // FULL batch on every worker
        let (h, v) = (cfg.hidden, cfg.vocab);
        let (hp, vp) = (h / n, v / n);
        let virt = self.ctx.virtual_mode();
        let acts = MemCategory::Activations;
        if let Some(tl) = self.ctx.timeline.as_mut() {
            tl.reset();
        }

        // per-worker replicated inputs
        let mut ids = Vec::with_capacity(n);
        let mut tgts = Vec::with_capacity(n);
        for w in 0..n {
            let mk = |t: &crate::tensor::IntTensor| {
                if virt { Buf::Virt(vec![b, cfg.seq]) } else { Buf::Ids(t.clone()) }
            };
            ids.push(self.ctx.alloc(w, acts, mk(&batch.ids))?);
            tgts.push(self.ctx.alloc(w, acts, mk(&batch.targets))?);
        }

        // ---------------- forward ----------------
        // embedding: each worker computes its hidden slice, allgather
        let mut x: Vec<TBuf> = Vec::with_capacity(n);
        for w in 0..n {
            x.push(self.ctx.alloc(w, acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?);
        }
        {
            let mut parts = Vec::with_capacity(n);
            for w in 0..n {
                let (wte, wpe) = match &self.state {
                    Some(s) => (Some(&s.emb[w].0), Some(&s.emb[w].1)),
                    None => (None, None),
                };
                let mut outs = self.ctx.call_op(
                    w,
                    Op::EmbFwd,
                    b,
                    n,
                    &[ids[w].buf.arg(), arg_of(wte), arg_of(wpe)],
                    &[acts],
                )?;
                parts.push(outs.pop().unwrap());
            }
            self.ctx
                .charge_comm("ag-emb", CommPrim::AllGather, x[0].buf.bytes());
            // ring-allgather the hidden slices: every worker receives the
            // other shards hop by hop through its own port, then assembles
            // the full hidden locally
            if !virt {
                let ports = self.ctx.ports();
                let slices: Vec<Vec<f32>> =
                    parts.iter().map(|p| p.f().data.clone()).collect();
                let gathered = comm::allgather_parts(ports, &slices);
                for (w, pieces) in gathered.into_iter().enumerate() {
                    if let Buf::Real(full) = &mut x[w].buf {
                        for (s, piece) in pieces.into_iter().enumerate() {
                            let t = HostTensor::from_vec(&[b, cfg.seq, hp], piece);
                            full.write_slice_last(s * hp, &t);
                        }
                    }
                }
            }
            for p in parts {
                self.ctx.free(p);
            }
        }

        struct SavedTp {
            x_in: Vec<TBuf>,
            a: Vec<TBuf>,
            x_mid: Vec<TBuf>,
            m: Vec<TBuf>,
        }
        let mut saved: Vec<SavedTp> = Vec::new();

        for l in 0..cfg.layers {
            // ln1 (replicated)
            let mut a = Vec::with_capacity(n);
            for w in 0..n {
                let g = self.rep_tensor(w, |r| &r.layers[l].ln1_g);
                let bb = self.rep_tensor(w, |r| &r.layers[l].ln1_b);
                let mut outs = self.ctx.call_op(
                    w,
                    Op::LnFwd,
                    b,
                    n,
                    &[x[w].buf.arg(), arg_of(g.as_ref()), arg_of(bb.as_ref())],
                    &[acts],
                )?;
                a.push(outs.pop().unwrap());
            }
            // attention partials + allreduce
            let mut parts = Vec::with_capacity(n);
            for w in 0..n {
                let sh = self.state.as_ref().map(|s| &s.layers[l].attn[w]);
                let mut outs = self.ctx.call_op(
                    w,
                    Op::AttnFwd,
                    b,
                    n,
                    &[
                        a[w].buf.arg(),
                        arg_of(sh.map(|s| &s.wqkv)),
                        arg_of(sh.map(|s| &s.bqkv)),
                        arg_of(sh.map(|s| &s.wo)),
                    ],
                    &[acts],
                )?;
                parts.push(outs.pop().unwrap());
            }
            allreduce_partials(&mut self.ctx, &mut parts);
            let mut x_mid = Vec::with_capacity(n);
            for (w, mut part) in parts.into_iter().enumerate() {
                let bo = self.rep_tensor(w, |r| &r.layers[l].bo);
                self.ctx.add_bias(&mut part, bo.as_ref());
                self.ctx.residual(&mut part, &x[w]);
                x_mid.push(part);
            }
            // ln2 + mlp partials + allreduce
            let mut m = Vec::with_capacity(n);
            for w in 0..n {
                let g = self.rep_tensor(w, |r| &r.layers[l].ln2_g);
                let bb = self.rep_tensor(w, |r| &r.layers[l].ln2_b);
                let mut outs = self.ctx.call_op(
                    w,
                    Op::LnFwd,
                    b,
                    n,
                    &[x_mid[w].buf.arg(), arg_of(g.as_ref()), arg_of(bb.as_ref())],
                    &[acts],
                )?;
                m.push(outs.pop().unwrap());
            }
            let mut parts = Vec::with_capacity(n);
            for w in 0..n {
                let sh = self.state.as_ref().map(|s| &s.layers[l].mlp[w]);
                let mut outs = self.ctx.call_op(
                    w,
                    Op::MlpFwd,
                    b,
                    n,
                    &[
                        m[w].buf.arg(),
                        arg_of(sh.map(|s| &s.w1)),
                        arg_of(sh.map(|s| &s.b1)),
                        arg_of(sh.map(|s| &s.w2)),
                    ],
                    &[acts],
                )?;
                parts.push(outs.pop().unwrap());
            }
            allreduce_partials(&mut self.ctx, &mut parts);
            let mut x_new = Vec::with_capacity(n);
            for (w, mut part) in parts.into_iter().enumerate() {
                let b2 = self.rep_tensor(w, |r| &r.layers[l].b2);
                self.ctx.add_bias(&mut part, b2.as_ref());
                self.ctx.residual(&mut part, &x_mid[w]);
                x_new.push(part);
            }
            saved.push(SavedTp { x_in: x, a, x_mid, m });
            x = x_new;
        }

        // final LN + LM head (allgather logits) + loss
        let mut xf = Vec::with_capacity(n);
        for w in 0..n {
            let g = self.rep_tensor(w, |r| &r.lnf_g);
            let bb = self.rep_tensor(w, |r| &r.lnf_b);
            let mut outs = self.ctx.call_op(
                w,
                Op::LnFwd,
                b,
                n,
                &[x[w].buf.arg(), arg_of(g.as_ref()), arg_of(bb.as_ref())],
                &[acts],
            )?;
            xf.push(outs.pop().unwrap());
        }
        let mut logit_parts = Vec::with_capacity(n);
        for w in 0..n {
            let wlm = self.state.as_ref().map(|s| &s.lm[w]);
            let mut outs = self.ctx.call_op(
                w,
                Op::LmheadFwd,
                b,
                n,
                &[xf[w].buf.arg(), arg_of(wlm)],
                &[acts],
            )?;
            logit_parts.push(outs.pop().unwrap());
        }
        let mut logits = Vec::with_capacity(n);
        for w in 0..n {
            logits.push(self.ctx.alloc(w, acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, v]))?);
        }
        self.ctx
            .charge_comm("ag-logits", CommPrim::AllGather, logits[0].buf.bytes());
        if !virt {
            let ports = self.ctx.ports();
            let slices: Vec<Vec<f32>> =
                logit_parts.iter().map(|p| p.f().data.clone()).collect();
            let gathered = comm::allgather_parts(ports, &slices);
            for (w, pieces) in gathered.into_iter().enumerate() {
                if let Buf::Real(full) = &mut logits[w].buf {
                    for (s, piece) in pieces.into_iter().enumerate() {
                        let t = HostTensor::from_vec(&[b, cfg.seq, vp], piece);
                        full.write_slice_last(s * vp, &t);
                    }
                }
            }
        }
        for p in logit_parts {
            self.ctx.free(p);
        }

        let mut loss = 0.0;
        let mut dlogits = Vec::with_capacity(n);
        for w in 0..n {
            let mut outs = self.ctx.call_op(
                w,
                Op::Xent,
                b,
                n,
                &[logits[w].buf.arg(), tgts[w].buf.arg()],
                &[acts, acts],
            )?;
            let dl = outs.pop().unwrap();
            let lbuf = outs.pop().unwrap();
            if w == 0 {
                loss = self.ctx.loss_of(&lbuf);
            }
            self.ctx.free(lbuf);
            dlogits.push(dl);
        }
        for l in logits {
            self.ctx.free(l);
        }
        for t in tgts {
            self.ctx.free(t);
        }

        // ---------------- backward ----------------
        // LM head: per-worker vocab slice of dlogits -> dx partials
        let mut dxf = Vec::with_capacity(n);
        for w in 0..n {
            let dl_w = self.ctx.col_slice(w, &dlogits[w], w * vp, vp, acts)?;
            let wlm = self.state.as_ref().map(|s| &s.lm[w]);
            let mut outs = self.ctx.call_op(
                w,
                Op::LmheadBwd,
                b,
                n,
                &[xf[w].buf.arg(), arg_of(wlm), dl_w.buf.arg()],
                &[acts, MemCategory::Grads],
            )?;
            let dwlm = outs.pop().unwrap();
            let dx = outs.pop().unwrap();
            if let Some(st) = self.state.as_mut() {
                st.g_lm[w].add_assign(dwlm.f());
            }
            self.ctx.free(dwlm);
            self.ctx.free(dl_w);
            dxf.push(dx);
        }
        for d in dlogits {
            self.ctx.free(d);
        }
        allreduce_partials(&mut self.ctx, &mut dxf);

        // final LN backward (replicated grads, no comm)
        let mut dx = Vec::with_capacity(n);
        for w in 0..n {
            let g = self.rep_tensor(w, |r| &r.lnf_g);
            let mut outs = self.ctx.call_op(
                w,
                Op::LnBwd,
                b,
                n,
                &[
                    x[w].buf.arg(),
                    arg_of(g.as_ref()),
                    dxf[w].buf.arg(),
                ],
                &[acts, MemCategory::Grads, MemCategory::Grads],
            )?;
            let db = outs.pop().unwrap();
            let dg = outs.pop().unwrap();
            let d = outs.pop().unwrap();
            if let Some(st) = self.state.as_mut() {
                st.g_rep[w].lnf_g.add_assign(dg.f());
                st.g_rep[w].lnf_b.add_assign(db.f());
            }
            self.ctx.free(db);
            self.ctx.free(dg);
            dx.push(d);
        }
        for d in dxf {
            self.ctx.free(d);
        }
        for t in xf {
            self.ctx.free(t);
        }
        for t in x {
            self.ctx.free(t);
        }

        for l in (0..cfg.layers).rev() {
            let SavedTp { x_in, a, x_mid, m } = saved.pop().unwrap();
            // b2 grads (replicated)
            for w in 0..n {
                if let Some(st) = self.state.as_mut() {
                    st.g_rep[w].layers[l].b2.add_assign(&dx[w].f().sum_leading());
                }
            }
            // mlp backward -> dm partials (allreduce)
            let mut dm = Vec::with_capacity(n);
            for w in 0..n {
                let sh = self.state.as_ref().map(|s| &s.layers[l].mlp[w]);
                let mut outs = self.ctx.call_op(
                    w,
                    Op::MlpBwd,
                    b,
                    n,
                    &[
                        m[w].buf.arg(),
                        arg_of(sh.map(|s| &s.w1)),
                        arg_of(sh.map(|s| &s.b1)),
                        arg_of(sh.map(|s| &s.w2)),
                        dx[w].buf.arg(),
                    ],
                    &[acts, MemCategory::Grads, MemCategory::Grads, MemCategory::Grads],
                )?;
                let dw2 = outs.pop().unwrap();
                let db1 = outs.pop().unwrap();
                let dw1 = outs.pop().unwrap();
                let d = outs.pop().unwrap();
                if let Some(st) = self.state.as_mut() {
                    let g = &mut st.g_layers[l].mlp[w];
                    g.w2.add_assign(dw2.f());
                    g.b1.add_assign(db1.f());
                    g.w1.add_assign(dw1.f());
                }
                self.ctx.free(dw2);
                self.ctx.free(db1);
                self.ctx.free(dw1);
                dm.push(d);
            }
            allreduce_partials(&mut self.ctx, &mut dm);
            // ln2 backward + residual accumulate
            for w in 0..n {
                let g = self.rep_tensor(w, |r| &r.layers[l].ln2_g);
                let mut outs = self.ctx.call_op(
                    w,
                    Op::LnBwd,
                    b,
                    n,
                    &[
                        x_mid[w].buf.arg(),
                        arg_of(g.as_ref()),
                        dm[w].buf.arg(),
                    ],
                    &[acts, MemCategory::Grads, MemCategory::Grads],
                )?;
                let db = outs.pop().unwrap();
                let dg = outs.pop().unwrap();
                let dxl = outs.pop().unwrap();
                if let Some(st) = self.state.as_mut() {
                    st.g_rep[w].layers[l].ln2_g.add_assign(dg.f());
                    st.g_rep[w].layers[l].ln2_b.add_assign(db.f());
                }
                self.ctx.free(db);
                self.ctx.free(dg);
                self.ctx.accumulate(&mut dx[w], &dxl);
                self.ctx.free(dxl);
            }
            for t in dm {
                self.ctx.free(t);
            }
            for t in m {
                self.ctx.free(t);
            }
            for t in x_mid {
                self.ctx.free(t);
            }
            // bo grads + attention backward
            for w in 0..n {
                if let Some(st) = self.state.as_mut() {
                    st.g_rep[w].layers[l].bo.add_assign(&dx[w].f().sum_leading());
                }
            }
            let mut da = Vec::with_capacity(n);
            for w in 0..n {
                let sh = self.state.as_ref().map(|s| &s.layers[l].attn[w]);
                let mut outs = self.ctx.call_op(
                    w,
                    Op::AttnBwd,
                    b,
                    n,
                    &[
                        a[w].buf.arg(),
                        arg_of(sh.map(|s| &s.wqkv)),
                        arg_of(sh.map(|s| &s.bqkv)),
                        arg_of(sh.map(|s| &s.wo)),
                        dx[w].buf.arg(),
                    ],
                    &[acts, MemCategory::Grads, MemCategory::Grads, MemCategory::Grads],
                )?;
                let dwo = outs.pop().unwrap();
                let dbq = outs.pop().unwrap();
                let dwq = outs.pop().unwrap();
                let d = outs.pop().unwrap();
                if let Some(st) = self.state.as_mut() {
                    let g = &mut st.g_layers[l].attn[w];
                    g.wo.add_assign(dwo.f());
                    g.bqkv.add_assign(dbq.f());
                    g.wqkv.add_assign(dwq.f());
                }
                self.ctx.free(dwo);
                self.ctx.free(dbq);
                self.ctx.free(dwq);
                da.push(d);
            }
            allreduce_partials(&mut self.ctx, &mut da);
            for w in 0..n {
                let g = self.rep_tensor(w, |r| &r.layers[l].ln1_g);
                let mut outs = self.ctx.call_op(
                    w,
                    Op::LnBwd,
                    b,
                    n,
                    &[
                        x_in[w].buf.arg(),
                        arg_of(g.as_ref()),
                        da[w].buf.arg(),
                    ],
                    &[acts, MemCategory::Grads, MemCategory::Grads],
                )?;
                let db = outs.pop().unwrap();
                let dg = outs.pop().unwrap();
                let dxl = outs.pop().unwrap();
                if let Some(st) = self.state.as_mut() {
                    st.g_rep[w].layers[l].ln1_g.add_assign(dg.f());
                    st.g_rep[w].layers[l].ln1_b.add_assign(db.f());
                }
                self.ctx.free(db);
                self.ctx.free(dg);
                self.ctx.accumulate(&mut dx[w], &dxl);
                self.ctx.free(dxl);
            }
            for t in da {
                self.ctx.free(t);
            }
            for t in a {
                self.ctx.free(t);
            }
            for t in x_in {
                self.ctx.free(t);
            }
        }

        // embedding backward: each worker takes its hidden slice
        for w in 0..n {
            let dx_w = self.ctx.col_slice(w, &dx[w], w * hp, hp, acts)?;
            let mut outs = self.ctx.call_op(
                w,
                Op::EmbBwd,
                b,
                n,
                &[ids[w].buf.arg(), dx_w.buf.arg()],
                &[MemCategory::Grads, MemCategory::Grads],
            )?;
            let dwpe = outs.pop().unwrap();
            let dwte = outs.pop().unwrap();
            if let Some(st) = self.state.as_mut() {
                st.g_emb[w].0.add_assign(dwte.f());
                st.g_emb[w].1.add_assign(dwpe.f());
            }
            self.ctx.free(dwte);
            self.ctx.free(dwpe);
            self.ctx.free(dx_w);
        }
        for t in dx {
            self.ctx.free(t);
        }
        for t in ids {
            self.ctx.free(t);
        }
        if let Some(tl) = self.ctx.timeline.as_mut() {
            tl.barrier();
        }
        debug_assert_eq!(
            self.ctx.cluster.fabric().in_flight(),
            0,
            "tp step left ring-fabric messages in flight"
        );
        self.last_loss = loss;
        Ok(loss)
    }

    fn gather_params(&self) -> ModelParams {
        let st = self.state.as_ref().expect("virtual mode");
        let cfg = &self.ctx.cfg;
        let mut out = ModelParams::zeros_like(cfg);
        out.wte = partition::unshard_cols(
            &st.emb.iter().map(|(a, _)| a.clone()).collect::<Vec<_>>(),
        );
        out.wpe = partition::unshard_cols(
            &st.emb.iter().map(|(_, b)| b.clone()).collect::<Vec<_>>(),
        );
        for (l, lp) in out.layers.iter_mut().enumerate() {
            let heads = cfg.heads;
            let hd = cfg.head_dim();
            lp.wqkv = partition::unshard_qkv_cols(
                &st.layers[l].attn.iter().map(|a| a.wqkv.clone()).collect::<Vec<_>>(),
                heads,
                hd,
            );
            lp.bqkv = partition::unshard_qkv_cols(
                &st.layers[l].attn.iter().map(|a| a.bqkv.clone()).collect::<Vec<_>>(),
                heads,
                hd,
            );
            lp.wo = partition::unshard_rows(
                &st.layers[l].attn.iter().map(|a| a.wo.clone()).collect::<Vec<_>>(),
            );
            let rep = &st.rep[0].layers[l];
            lp.ln1_g = rep.ln1_g.clone();
            lp.ln1_b = rep.ln1_b.clone();
            lp.bo = rep.bo.clone();
            lp.ln2_g = rep.ln2_g.clone();
            lp.ln2_b = rep.ln2_b.clone();
            lp.mlp = MlpParams::Dense {
                w1: partition::unshard_cols(
                    &st.layers[l].mlp.iter().map(|m| m.w1.clone()).collect::<Vec<_>>(),
                ),
                b1: partition::unshard_cols(
                    &st.layers[l].mlp.iter().map(|m| m.b1.clone()).collect::<Vec<_>>(),
                ),
                w2: partition::unshard_rows(
                    &st.layers[l].mlp.iter().map(|m| m.w2.clone()).collect::<Vec<_>>(),
                ),
                b2: rep.b2.clone(),
            };
        }
        out.lnf_g = st.rep[0].lnf_g.clone();
        out.lnf_b = st.rep[0].lnf_b.clone();
        out.wlm = partition::unshard_cols(&st.lm);
        out
    }

    fn gather_grads(&self) -> ModelParams {
        // identical reconstruction over the gradient shards
        let st = self.state.as_ref().expect("virtual mode");
        let mut tmp = TpEngine {
            ctx: Ctx {
                cfg: self.ctx.cfg.clone(),
                par: self.ctx.par.clone(),
                exec: crate::runtime::Exec::Oracle,
                cluster: crate::cluster::Cluster::new(self.ctx.n(), None),
                timeline: None,
            },
            state: Some(TpState {
                emb: st.g_emb.clone(),
                layers: st
                    .g_layers
                    .iter()
                    .map(|l| LayerShards { attn: l.attn.clone(), mlp: l.mlp.clone() })
                    .collect(),
                lm: st.g_lm.clone(),
                rep: st.g_rep.clone(),
                g_emb: st.g_emb.clone(),
                g_layers: Vec::new(),
                g_lm: Vec::new(),
                g_rep: st.g_rep.clone(),
            }),
            last_loss: 0.0,
        };
        // keep the grad-rep values in the "param" slots for reconstruction
        tmp.state.as_mut().unwrap().g_layers = Vec::new();
        tmp.gather_params()
    }

    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor)) {
        let Some(st) = self.state.as_mut() else { return };
        for (p, g) in st.emb.iter_mut().zip(&st.g_emb) {
            f(&mut p.0, &g.0);
            f(&mut p.1, &g.1);
        }
        for (pl, gl) in st.layers.iter_mut().zip(&st.g_layers) {
            for (p, g) in pl.attn.iter_mut().zip(&gl.attn) {
                f(&mut p.wqkv, &g.wqkv);
                f(&mut p.bqkv, &g.bqkv);
                f(&mut p.wo, &g.wo);
            }
            for (p, g) in pl.mlp.iter_mut().zip(&gl.mlp) {
                f(&mut p.w1, &g.w1);
                f(&mut p.b1, &g.b1);
                f(&mut p.w2, &g.w2);
            }
        }
        for (p, g) in st.lm.iter_mut().zip(&st.g_lm) {
            f(p, g);
        }
        for (p, g) in st.rep.iter_mut().zip(&st.g_rep) {
            let mut gs: Vec<*const HostTensor> = Vec::new();
            g.visit(&mut |t| gs.push(t));
            let mut i = 0;
            p.visit_mut(&mut |t| {
                // SAFETY: parallel traversal of structurally-equal trees
                f(t, unsafe { &*gs[i] });
                i += 1;
            });
        }
    }

    fn zero_grads(&mut self) {
        let Some(st) = self.state.as_mut() else { return };
        for g in &mut st.g_emb {
            g.0.data.fill(0.0);
            g.1.data.fill(0.0);
        }
        for gl in &mut st.g_layers {
            for g in &mut gl.attn {
                g.wqkv.data.fill(0.0);
                g.bqkv.data.fill(0.0);
                g.wo.data.fill(0.0);
            }
            for g in &mut gl.mlp {
                g.w1.data.fill(0.0);
                g.b1.data.fill(0.0);
                g.w2.data.fill(0.0);
            }
        }
        for g in &mut st.g_lm {
            g.data.fill(0.0);
        }
        for g in &mut st.g_rep {
            g.visit_mut(&mut |t| t.data.fill(0.0));
        }
    }

    fn ctx(&self) -> &Ctx {
        &self.ctx
    }
    fn ctx_mut(&mut self) -> &mut Ctx {
        &mut self.ctx
    }
}
