//! Rotated Tensor Parallelism — the paper's contribution (§3, §4).
//!
//! Both activations (batch dimension) and parameters are sharded; no
//! worker ever holds more than one shard of a unit. During forward, each
//! unit's shards rotate CLOCKWISE around the ring between the N partition
//! compute steps; during backward they rotate COUNTER-CLOCKWISE together
//! with a traveling gradient buffer, so after N-1 steps every weight
//! shard is back home and its gradient has accumulated every worker's
//! batch contribution — replacing DDP's allreduce entirely.
//!
//! Every rotation hop is a true neighbor exchange on the rank-local ring
//! fabric: worker `w` pushes its shard out of its own `RingPort` and pulls
//! its upstream neighbor's in — no worker ever reaches into another
//! worker's buffers. Shard ids ride the fabric in virtual mode, so the
//! per-hop schedule (and its trace) is mode-independent.
//!
//! Variants (paper §3):
//! - **In-place**: rotation is blocking and reuses the live shard buffer —
//!   zero extra memory (Table 1 row `RTP Inplace`), serialized comm.
//! - **Out-of-place**: a persistent per-worker rotation buffer
//!   (`max(W,G)/N` — Table 1 row `RTP`) double-buffers the in-flight
//!   shard so rotation overlaps compute on a second stream; with
//!   `recycle` (§3.4.4) the buffer's bytes are repurposed for the
//!   logits/loss activations between its forward TTL and the backward.
//!
//! Partition strategies (§3.2): Output-Partition (embedding, LM head —
//! merge = concat), Number-of-head-Partition (attention — merge = add),
//! Megatron-pair MLP (merge = add), Expert-Partition (MoE — rotation
//! replaces the all-to-all).

use anyhow::Result;

use crate::cluster::TraceEvent;
use crate::comm::{rotation::shard_at, CommPrim, RingPort, RotationDir};
use crate::config::ModelCfg;
use crate::memory::tracker::MemCategory;
use crate::model::partition::{self, AttnShard, MlpShard};
use crate::model::ops::Op;
use crate::model::{ExpertParams, MlpParams, ModelParams};
use crate::runtime::{arg_of, Buf};
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

use super::common::{replicated_elems, scatter_dgates, top1_gates, Batch, Ctx, RepParams, TBuf};
use super::Engine;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RtpVariant {
    InPlace,
    OutOfPlace { recycle: bool },
}

impl RtpVariant {
    fn overlapped(&self) -> bool {
        matches!(self, RtpVariant::OutOfPlace { .. })
    }
}

// ---------------------------------------------------------------------------
// rotating rings
// ---------------------------------------------------------------------------

/// A ring of rotating shard payloads: `ids[w]` names the shard currently
/// held by worker `w`; `data` carries the real tensors (None in virtual
/// mode). Rotation is a true neighbor exchange through the rank-local
/// fabric: every worker sends its payload out of its own port and receives
/// its upstream neighbor's — ids and data ride the same hop, so the
/// schedule is identical in virtual mode (ids only) and real mode.
#[derive(Debug)]
struct Ring<T> {
    ids: Vec<usize>,
    data: Option<Vec<T>>,
}

impl<T: 'static> Ring<T> {
    fn home(n: usize, data: Option<Vec<T>>) -> Self {
        if let Some(d) = &data {
            assert_eq!(d.len(), n);
        }
        Ring { ids: (0..n).collect(), data }
    }

    /// One rotation hop through the fabric in direction `dir`. Real mode
    /// sends ONE `(id, payload)` message per rank so the fabric's hop and
    /// message accounting is identical to virtual mode (ids only).
    fn rotate(&mut self, ports: &[RingPort], dir: RotationDir) {
        let n = self.ids.len();
        if n <= 1 {
            return;
        }
        match self.data.as_mut() {
            None => crate::comm::rotate_ring(ports, &mut self.ids, dir),
            Some(d) => {
                let ids = std::mem::take(&mut self.ids);
                let data = std::mem::take(d);
                for (w, msg) in ids.into_iter().zip(data).enumerate() {
                    ports[w].send(dir.send_peer(w, n), msg);
                }
                for (w, port) in ports.iter().enumerate() {
                    let (id, payload): (usize, T) = port.recv(dir.recv_peer(w, n));
                    self.ids.push(id);
                    d.push(payload);
                }
            }
        }
    }

    fn id(&self, w: usize) -> usize {
        self.ids[w]
    }

    fn get(&self, w: usize) -> Option<&T> {
        self.data.as_ref().map(|d| &d[w])
    }

    fn get_mut(&mut self, w: usize) -> Option<&mut T> {
        self.data.as_mut().map(|d| &mut d[w])
    }
}

#[derive(Debug, Clone)]
struct EmbShard {
    wte: HostTensor,
    wpe: HostTensor,
}

#[derive(Debug, Clone)]
enum MlpShardV {
    Dense(MlpShard),
    /// Expert-Partition: a contiguous group of E/N whole experts.
    Experts(Vec<ExpertParams>),
}

struct Rings {
    emb: Ring<EmbShard>,
    attn: Vec<Ring<AttnShard>>,
    mlp: Vec<Ring<MlpShardV>>,
    lm: Ring<HostTensor>,
}

/// Home gradient storage, indexed by SHARD ID (not worker — though after
/// a full step they coincide).
struct HomeGrads {
    emb: Option<Vec<EmbShard>>,
    attn: Option<Vec<Vec<AttnShard>>>,
    mlp: Option<Vec<Vec<MlpShardV>>>,
    lm: Option<Vec<HostTensor>>,
}

/// Per-unit rotation message sizes (the FlatParameter the ring moves).
#[derive(Debug, Clone, Copy)]
struct ShardBytes {
    emb: u64,
    attn: u64,
    mlp: u64,
    lm: u64,
}

impl ShardBytes {
    fn of(cfg: &ModelCfg, n: usize) -> ShardBytes {
        let (v, h, s, f) = (cfg.vocab, cfg.hidden, cfg.seq, cfg.ffn);
        let hp = h / n;
        let mlp = if cfg.is_moe() {
            let per = cfg.experts / n;
            per * (h * cfg.expert_ffn + cfg.expert_ffn + cfg.expert_ffn * h)
        } else {
            let fp = f / n;
            h * fp + fp + fp * h
        };
        ShardBytes {
            emb: ((v * hp + s * hp) * 4) as u64,
            attn: ((h * 3 * hp + 3 * hp + hp * h) * 4) as u64,
            mlp: (mlp * 4) as u64,
            lm: ((h * (v / n)) * 4) as u64,
        }
    }

    /// Total sharded bytes per worker = (W_sharded)/N.
    fn total(&self, layers: usize) -> u64 {
        self.emb + layers as u64 * (self.attn + self.mlp) + self.lm
    }
}

fn zero_like_attn(s: &AttnShard) -> AttnShard {
    AttnShard {
        wqkv: HostTensor::zeros(&s.wqkv.shape),
        bqkv: HostTensor::zeros(&s.bqkv.shape),
        wo: HostTensor::zeros(&s.wo.shape),
    }
}

fn zero_like_mlp(s: &MlpShardV) -> MlpShardV {
    match s {
        MlpShardV::Dense(m) => MlpShardV::Dense(MlpShard {
            w1: HostTensor::zeros(&m.w1.shape),
            b1: HostTensor::zeros(&m.b1.shape),
            w2: HostTensor::zeros(&m.w2.shape),
        }),
        MlpShardV::Experts(ex) => MlpShardV::Experts(
            ex.iter()
                .map(|e| ExpertParams {
                    w1: HostTensor::zeros(&e.w1.shape),
                    b1: HostTensor::zeros(&e.b1.shape),
                    w2: HostTensor::zeros(&e.w2.shape),
                })
                .collect(),
        ),
    }
}

// ---------------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------------

pub struct RtpEngine {
    pub ctx: Ctx,
    pub variant: RtpVariant,
    rings: Rings,
    grads: HomeGrads,
    rep: Option<Vec<RepParams>>,
    g_rep: Option<Vec<RepParams>>,
    /// Out-of-place: the persistent rotation buffer, one per worker.
    comm_bufs: Vec<TBuf>,
    bytes: ShardBytes,
    last_loss: f32,
}

impl RtpEngine {
    pub fn new(mut ctx: Ctx, seed: u64, variant: RtpVariant) -> Result<Self> {
        let n = ctx.n();
        let cfg = ctx.cfg.clone();
        let virt = ctx.virtual_mode();
        if cfg.is_moe() {
            assert_eq!(cfg.experts % n, 0, "experts must divide over workers");
        }

        let bytes = ShardBytes::of(&cfg, n);
        let (rings, grads, rep, g_rep) = if virt {
            (
                Rings {
                    emb: Ring::home(n, None),
                    attn: (0..cfg.layers).map(|_| Ring::home(n, None)).collect(),
                    mlp: (0..cfg.layers).map(|_| Ring::home(n, None)).collect(),
                    lm: Ring::home(n, None),
                },
                HomeGrads { emb: None, attn: None, mlp: None, lm: None },
                None,
                None,
            )
        } else {
            let full = ModelParams::init(&cfg, &mut Rng::new(seed));
            let heads = cfg.heads;
            let hd = cfg.head_dim();
            let emb_shards: Vec<EmbShard> = (0..n)
                .map(|s| EmbShard {
                    wte: partition::shard_cols(&full.wte, s, n),
                    wpe: partition::shard_cols(&full.wpe, s, n),
                })
                .collect();
            let attn_rings: Vec<Ring<AttnShard>> = full
                .layers
                .iter()
                .map(|lp| {
                    Ring::home(
                        n,
                        Some(
                            (0..n)
                                .map(|s| {
                                    partition::attn_shard(
                                        &lp.wqkv, &lp.bqkv, &lp.wo, s, n, heads, hd,
                                    )
                                })
                                .collect(),
                        ),
                    )
                })
                .collect();
            let mlp_rings: Vec<Ring<MlpShardV>> = full
                .layers
                .iter()
                .map(|lp| {
                    Ring::home(
                        n,
                        Some(
                            (0..n)
                                .map(|s| match &lp.mlp {
                                    MlpParams::Dense { w1, b1, w2, .. } => MlpShardV::Dense(
                                        partition::mlp_shard(w1, b1, w2, s, n),
                                    ),
                                    MlpParams::Moe { experts, .. } => MlpShardV::Experts(
                                        partition::expert_range(s, n, cfg.experts)
                                            .map(|e| experts[e].clone())
                                            .collect(),
                                    ),
                                })
                                .collect(),
                        ),
                    )
                })
                .collect();
            let lm_shards: Vec<HostTensor> =
                (0..n).map(|s| partition::shard_cols(&full.wlm, s, n)).collect();
            let grads = HomeGrads {
                emb: Some(
                    emb_shards
                        .iter()
                        .map(|e| EmbShard {
                            wte: HostTensor::zeros(&e.wte.shape),
                            wpe: HostTensor::zeros(&e.wpe.shape),
                        })
                        .collect(),
                ),
                attn: Some(
                    attn_rings
                        .iter()
                        .map(|r| r.data.as_ref().unwrap().iter().map(zero_like_attn).collect())
                        .collect(),
                ),
                mlp: Some(
                    mlp_rings
                        .iter()
                        .map(|r| r.data.as_ref().unwrap().iter().map(zero_like_mlp).collect())
                        .collect(),
                ),
                lm: Some(lm_shards.iter().map(|t| HostTensor::zeros(&t.shape)).collect()),
            };
            let rep = vec![RepParams::from_full(&full); n];
            let g_rep = rep.iter().map(|r| r.zeros_like()).collect();
            (
                Rings {
                    emb: Ring::home(n, Some(emb_shards)),
                    attn: attn_rings,
                    mlp: mlp_rings,
                    lm: Ring::home(n, Some(lm_shards)),
                },
                grads,
                Some(rep),
                Some(g_rep),
            )
        };

        // persistent residency: weight shard + grad shard + replicated ×2
        let sharded = bytes.total(cfg.layers);
        let rep_bytes = (replicated_elems(&cfg) * 4) as u64;
        for w in 0..n {
            ctx.cluster.tracker(w).alloc(MemCategory::Weights, sharded + rep_bytes)?;
            ctx.cluster.tracker(w).alloc(MemCategory::Grads, sharded + rep_bytes)?;
        }
        // out-of-place: one persistent rotation buffer per worker,
        // sized for the largest in-flight message: max(W,G)/N per Table 1
        // (weights and grads are equal-sized here, and backward moves both
        // => the buffer holds one unit's weight+grad shard pair).
        let mut comm_bufs = Vec::new();
        if variant.overlapped() {
            let unit_max = bytes
                .emb
                .max(bytes.attn)
                .max(bytes.mlp)
                .max(bytes.lm);
            for w in 0..n {
                comm_bufs.push(ctx.alloc(
                    w,
                    MemCategory::CommBuf,
                    Buf::Virt(vec![(2 * unit_max / 4) as usize]),
                )?);
            }
        }

        Ok(RtpEngine {
            ctx,
            variant,
            rings,
            grads,
            rep,
            g_rep,
            comm_bufs,
            bytes,
            last_loss: 0.0,
        })
    }

    /// Charge one rotation boundary on the timeline and step the ring one
    /// hop through the fabric. `fwd` chooses direction; `bytes` is the
    /// per-worker message size (backward doubles it: weights + traveling
    /// grads).
    fn rotate<T: 'static>(
        ctx: &mut Ctx,
        variant: RtpVariant,
        ring: &mut Ring<T>,
        gring: Option<&mut Ring<T>>,
        bytes: u64,
        fwd: bool,
        step: usize,
    ) {
        let msg = if fwd { bytes } else { 2 * bytes };
        match variant {
            RtpVariant::InPlace => {
                if let Some(tl) = ctx.timeline.as_mut() {
                    tl.comm_blocking("rotate", CommPrim::Rotation, msg);
                }
            }
            RtpVariant::OutOfPlace { .. } => {
                // overlap was charged eagerly before the step's compute
                // (see step()); nothing blocking here.
            }
        }
        let dir = if fwd { RotationDir::Clockwise } else { RotationDir::CounterClockwise };
        let ports = ctx.ports();
        ring.rotate(ports, dir);
        if let Some(g) = gring {
            g.rotate(ports, dir);
        }
        ctx.trace(TraceEvent::Rotate {
            dir: if fwd { "cw" } else { "ccw" },
            bytes_per_worker: msg,
            step,
        });
    }

    /// Out-of-place: charge the eager async rotation that overlaps this
    /// step's compute; returns the token to wait on at the boundary.
    fn oop_prefetch(
        ctx: &mut Ctx,
        variant: RtpVariant,
        bytes: u64,
        fwd: bool,
    ) -> Option<crate::perfmodel::Token> {
        if !variant.overlapped() {
            return None;
        }
        let msg = if fwd { bytes } else { 2 * bytes };
        ctx.timeline
            .as_mut()
            .map(|tl| tl.comm_async_eager("rotate", CommPrim::Rotation, msg))
    }

    fn oop_wait(ctx: &mut Ctx, tok: Option<crate::perfmodel::Token>) {
        if let (Some(tl), Some(tok)) = (ctx.timeline.as_mut(), tok) {
            tl.wait(tok);
        }
    }
}

/// Landing scale: batch-sharded loss means are averaged over workers.
fn land_scale(n: usize) -> f32 {
    1.0 / n as f32
}

impl Engine for RtpEngine {
    fn name(&self) -> String {
        match self.variant {
            RtpVariant::InPlace => "rtp-inplace".to_string(),
            RtpVariant::OutOfPlace { recycle: true } => "rtp-outofplace".to_string(),
            RtpVariant::OutOfPlace { recycle: false } => {
                "rtp-outofplace-norecycle".to_string()
            }
        }
    }

    fn step(&mut self, batch: &Batch) -> Result<f32> {
        let n = self.ctx.n();
        let cfg = self.ctx.cfg.clone();
        let b = batch.ids.shape[0] / n; // local batch
        let (h, v) = (cfg.hidden, cfg.vocab);
        let (hp, vp) = (h / n, v / n);
        let virt = self.ctx.virtual_mode();
        let acts = MemCategory::Activations;
        let variant = self.variant;
        if let Some(tl) = self.ctx.timeline.as_mut() {
            tl.reset();
        }
        self.ctx.cluster.trace.phase("forward");

        // worker-local batch shards
        let mut ids = Vec::with_capacity(n);
        let mut tgts = Vec::with_capacity(n);
        for w in 0..n {
            let shard = batch.shard(w, n);
            let mk = |t: &crate::tensor::IntTensor| {
                if virt { Buf::Virt(vec![b, cfg.seq]) } else { Buf::Ids(t.clone()) }
            };
            ids.push(self.ctx.alloc(w, acts, mk(&shard.ids))?);
            tgts.push(self.ctx.alloc(w, acts, mk(&shard.targets))?);
        }

        // ---------------- forward ----------------
        // embedding: Output-Partition, each worker assembles the FULL
        // hidden locally across the N rotation steps (no activation comm!)
        let mut x: Vec<TBuf> = Vec::with_capacity(n);
        for w in 0..n {
            x.push(self.ctx.alloc(w, acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?);
        }
        for t in 0..n {
            let tok = if t + 1 < n {
                Self::oop_prefetch(&mut self.ctx, variant, self.bytes.emb, true)
            } else {
                None
            };
            for w in 0..n {
                let sid = self.rings.emb.id(w);
                let sh = self.rings.emb.get(w);
                let mut outs = self.ctx.call_op(
                    w,
                    Op::EmbFwd,
                    b,
                    n,
                    &[ids[w].buf.arg(), arg_of(sh.map(|s| &s.wte)), arg_of(sh.map(|s| &s.wpe))],
                    &[acts],
                )?;
                let part = outs.pop().unwrap();
                self.ctx.write_col_slice(&mut x[w], sid * hp, &part);
                self.ctx.free(part);
                self.ctx.trace(TraceEvent::Compute {
                    worker: w,
                    unit: "emb".to_string(),
                    shard: sid,
                    step: t,
                });
            }
            if t + 1 < n {
                Self::oop_wait(&mut self.ctx, tok);
                Self::rotate(&mut self.ctx, variant, &mut self.rings.emb, None, self.bytes.emb, true, t);
            }
        }

        struct SavedRtp {
            x_in: Vec<TBuf>,
            a: Vec<TBuf>,
            x_mid: Vec<TBuf>,
            m: Vec<TBuf>,
            probs: Vec<TBuf>,
            gates: Vec<Vec<TBuf>>, // [worker][expert]
        }
        let mut saved: Vec<SavedRtp> = Vec::new();

        for l in 0..cfg.layers {
            // ln1 (replicated)
            let mut a = Vec::with_capacity(n);
            for w in 0..n {
                let rep = self.rep.as_ref().map(|r| &r[w].layers[l]);
                let mut outs = self.ctx.call_op(
                    w,
                    Op::LnFwd,
                    b,
                    n,
                    &[
                        x[w].buf.arg(),
                        arg_of(rep.map(|r| &r.ln1_g)),
                        arg_of(rep.map(|r| &r.ln1_b)),
                    ],
                    &[acts],
                )?;
                a.push(outs.pop().unwrap());
            }
            // attention: rotation loop, sum-merge
            let mut acc: Vec<TBuf> = Vec::with_capacity(n);
            for w in 0..n {
                acc.push(self.ctx.alloc(w, acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?);
            }
            for t in 0..n {
                let tok = if t + 1 < n {
                    Self::oop_prefetch(&mut self.ctx, variant, self.bytes.attn, true)
                } else {
                    None
                };
                for w in 0..n {
                    let sid = self.rings.attn[l].id(w);
                    let sh = self.rings.attn[l].get(w);
                    let mut outs = self.ctx.call_op(
                        w,
                        Op::AttnFwd,
                        b,
                        n,
                        &[
                            a[w].buf.arg(),
                            arg_of(sh.map(|s| &s.wqkv)),
                            arg_of(sh.map(|s| &s.bqkv)),
                            arg_of(sh.map(|s| &s.wo)),
                        ],
                        &[acts],
                    )?;
                    let part = outs.pop().unwrap();
                    self.ctx.accumulate(&mut acc[w], &part);
                    self.ctx.free(part);
                    self.ctx.trace(TraceEvent::Compute {
                        worker: w,
                        unit: format!("attn.l{l}"),
                        shard: sid,
                        step: t,
                    });
                }
                if t + 1 < n {
                    Self::oop_wait(&mut self.ctx, tok);
                    Self::rotate(
                        &mut self.ctx,
                        variant,
                        &mut self.rings.attn[l],
                        None,
                        self.bytes.attn,
                        true,
                        t,
                    );
                }
            }
            let mut x_mid = Vec::with_capacity(n);
            for (w, mut part) in acc.into_iter().enumerate() {
                let bo = self.rep.as_ref().map(|r| r[w].layers[l].bo.clone());
                self.ctx.add_bias(&mut part, bo.as_ref());
                self.ctx.residual(&mut part, &x[w]);
                x_mid.push(part);
            }
            // ln2
            let mut m = Vec::with_capacity(n);
            for w in 0..n {
                let rep = self.rep.as_ref().map(|r| &r[w].layers[l]);
                let mut outs = self.ctx.call_op(
                    w,
                    Op::LnFwd,
                    b,
                    n,
                    &[
                        x_mid[w].buf.arg(),
                        arg_of(rep.map(|r| &r.ln2_g)),
                        arg_of(rep.map(|r| &r.ln2_b)),
                    ],
                    &[acts],
                )?;
                m.push(outs.pop().unwrap());
            }
            // mlp / moe: rotation loop, sum-merge
            let mut probs: Vec<TBuf> = Vec::new();
            let mut gates: Vec<Vec<TBuf>> = Vec::new();
            if cfg.is_moe() {
                // replicated router runs once per worker
                for w in 0..n {
                    let rep = self.rep.as_ref().map(|r| &r[w].layers[l]);
                    let wr = rep.and_then(|r| r.wr.as_ref());
                    let mut outs = self.ctx.call_op(
                        w,
                        Op::RouterFwd,
                        b,
                        n,
                        &[m[w].buf.arg(), arg_of(wr)],
                        &[acts],
                    )?;
                    let p = outs.pop().unwrap();
                    let gate_bufs: Vec<Buf> = if virt {
                        (0..cfg.experts).map(|_| Buf::Virt(vec![b, cfg.seq])).collect()
                    } else {
                        top1_gates(p.f(), cfg.experts).into_iter().map(Buf::Real).collect()
                    };
                    let mut gw = Vec::with_capacity(cfg.experts);
                    for g in gate_bufs {
                        gw.push(self.ctx.alloc(w, acts, g)?);
                    }
                    probs.push(p);
                    gates.push(gw);
                }
            }
            let mut acc: Vec<TBuf> = Vec::with_capacity(n);
            for w in 0..n {
                acc.push(self.ctx.alloc(w, acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?);
            }
            for t in 0..n {
                let tok = if t + 1 < n {
                    Self::oop_prefetch(&mut self.ctx, variant, self.bytes.mlp, true)
                } else {
                    None
                };
                for w in 0..n {
                    let sid = self.rings.mlp[l].id(w);
                    if !cfg.is_moe() {
                        let sh = self.rings.mlp[l].get(w).map(|s| match s {
                            MlpShardV::Dense(d) => d,
                            _ => unreachable!(),
                        });
                        let mut outs = self.ctx.call_op(
                            w,
                            Op::MlpFwd,
                            b,
                            n,
                            &[
                                m[w].buf.arg(),
                                arg_of(sh.map(|s| &s.w1)),
                                arg_of(sh.map(|s| &s.b1)),
                                arg_of(sh.map(|s| &s.w2)),
                            ],
                            &[acts],
                        )?;
                        let part = outs.pop().unwrap();
                        self.ctx.accumulate(&mut acc[w], &part);
                        self.ctx.free(part);
                    } else {
                        // every expert in the held group visits this worker
                        let per = cfg.experts / n;
                        for k in 0..per {
                            let e_global = sid * per + k;
                            let ex = self.rings.mlp[l].get(w).map(|s| match s {
                                MlpShardV::Experts(ex) => &ex[k],
                                _ => unreachable!(),
                            });
                            let mut outs = self.ctx.call_op(
                                w,
                                Op::MoeFwd,
                                b,
                                n,
                                &[
                                    m[w].buf.arg(),
                                    gates[w][e_global].buf.arg(),
                                    arg_of(ex.map(|x| &x.w1)),
                                    arg_of(ex.map(|x| &x.b1)),
                                    arg_of(ex.map(|x| &x.w2)),
                                ],
                                &[acts],
                            )?;
                            let part = outs.pop().unwrap();
                            self.ctx.accumulate(&mut acc[w], &part);
                            self.ctx.free(part);
                        }
                    }
                    self.ctx.trace(TraceEvent::Compute {
                        worker: w,
                        unit: format!("mlp.l{l}"),
                        shard: sid,
                        step: t,
                    });
                }
                if t + 1 < n {
                    Self::oop_wait(&mut self.ctx, tok);
                    Self::rotate(
                        &mut self.ctx,
                        variant,
                        &mut self.rings.mlp[l],
                        None,
                        self.bytes.mlp,
                        true,
                        t,
                    );
                }
            }
            let mut x_new = Vec::with_capacity(n);
            for (w, mut part) in acc.into_iter().enumerate() {
                let b2 = self.rep.as_ref().map(|r| r[w].layers[l].b2.clone());
                self.ctx.add_bias(&mut part, b2.as_ref());
                self.ctx.residual(&mut part, &x_mid[w]);
                x_new.push(part);
            }
            saved.push(SavedRtp { x_in: x, a, x_mid, m, probs, gates });
            x = x_new;
        }

        // final LN
        let mut xf = Vec::with_capacity(n);
        for w in 0..n {
            let rep = self.rep.as_ref().map(|r| &r[w]);
            let mut outs = self.ctx.call_op(
                w,
                Op::LnFwd,
                b,
                n,
                &[
                    x[w].buf.arg(),
                    arg_of(rep.map(|r| &r.lnf_g)),
                    arg_of(rep.map(|r| &r.lnf_b)),
                ],
                &[acts],
            )?;
            xf.push(outs.pop().unwrap());
        }

        // LM head: Output-Partition; full local logits assembled over the
        // rotation steps
        let mut logits = Vec::with_capacity(n);
        for w in 0..n {
            logits.push(self.ctx.alloc(w, acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, v]))?);
        }
        for t in 0..n {
            let tok = if t + 1 < n {
                Self::oop_prefetch(&mut self.ctx, variant, self.bytes.lm, true)
            } else {
                None
            };
            for w in 0..n {
                let sid = self.rings.lm.id(w);
                let sh = self.rings.lm.get(w);
                let mut outs = self.ctx.call_op(
                    w,
                    Op::LmheadFwd,
                    b,
                    n,
                    &[xf[w].buf.arg(), arg_of(sh)],
                    &[acts],
                )?;
                let part = outs.pop().unwrap();
                self.ctx.write_col_slice(&mut logits[w], sid * vp, &part);
                self.ctx.free(part);
                self.ctx.trace(TraceEvent::Compute {
                    worker: w,
                    unit: "lmhead".to_string(),
                    shard: sid,
                    step: t,
                });
            }
            if t + 1 < n {
                Self::oop_wait(&mut self.ctx, tok);
                Self::rotate(&mut self.ctx, variant, &mut self.rings.lm, None, self.bytes.lm, true, t);
            }
        }

        // §3.4.4 buffer recycling: the rotation buffer's TTL ended with the
        // last LM-head rotation; its bytes serve the loss activations.
        let recycle = matches!(variant, RtpVariant::OutOfPlace { recycle: true });
        if recycle {
            for tb in &self.comm_bufs {
                self.ctx.recycle(tb, MemCategory::Activations);
            }
        }

        // loss
        self.ctx.cluster.trace.phase("loss");
        let mut loss_sum = 0.0;
        let mut dlogits = Vec::with_capacity(n);
        for w in 0..n {
            let mut outs = self.ctx.call_op(
                w,
                Op::Xent,
                b,
                n,
                &[logits[w].buf.arg(), tgts[w].buf.arg()],
                &[acts, acts],
            )?;
            let dl = outs.pop().unwrap();
            let lb = outs.pop().unwrap();
            loss_sum += self.ctx.loss_of(&lb);
            self.ctx.free(lb);
            dlogits.push(dl);
        }
        for t in logits {
            self.ctx.free(t);
        }
        for t in tgts {
            self.ctx.free(t);
        }
        if recycle {
            // backward rotations need the buffer again
            for tb in &self.comm_bufs {
                self.ctx.recycle(tb, MemCategory::CommBuf);
            }
        }

        // ---------------- backward ----------------
        self.ctx.cluster.trace.phase("backward");
        let scale = land_scale(n);

        // LM head backward: ccw rotation with traveling grads
        let mut dxf: Vec<TBuf> = Vec::with_capacity(n);
        for w in 0..n {
            dxf.push(self.ctx.alloc(w, acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?);
        }
        {
            let mut gring: Ring<HostTensor> = Ring {
                ids: self.rings.lm.ids.clone(),
                data: self.rings.lm.data.as_ref().map(|d| {
                    d.iter().map(|t| HostTensor::zeros(&t.shape)).collect()
                }),
            };
            for t in 0..n {
                let tok = if t + 1 < n {
                    Self::oop_prefetch(&mut self.ctx, variant, self.bytes.lm, false)
                } else {
                    None
                };
                for w in 0..n {
                    let sid = self.rings.lm.id(w);
                    let dl_w = self.ctx.col_slice(w, &dlogits[w], sid * vp, vp, acts)?;
                    let sh = self.rings.lm.get(w);
                    let mut outs = self.ctx.call_op(
                        w,
                        Op::LmheadBwd,
                        b,
                        n,
                        &[xf[w].buf.arg(), arg_of(sh), dl_w.buf.arg()],
                        &[acts, MemCategory::Grads],
                    )?;
                    let dwlm = outs.pop().unwrap();
                    let dx = outs.pop().unwrap();
                    if let Some(g) = gring.get_mut(w) {
                        g.add_assign(dwlm.f());
                    }
                    self.ctx.accumulate(&mut dxf[w], &dx);
                    self.ctx.free(dx);
                    self.ctx.free(dwlm);
                    self.ctx.free(dl_w);
                    self.ctx.trace(TraceEvent::Compute {
                        worker: w,
                        unit: "lmhead.bwd".to_string(),
                        shard: sid,
                        step: t,
                    });
                }
                if t + 1 < n {
                    Self::oop_wait(&mut self.ctx, tok);
                    Self::rotate(
                        &mut self.ctx,
                        variant,
                        &mut self.rings.lm,
                        Some(&mut gring),
                        self.bytes.lm,
                        false,
                        t,
                    );
                }
            }
            // land home (ids[w] == w now)
            debug_assert_eq!(gring.ids, (0..n).collect::<Vec<_>>());
            if let (Some(home), Some(data)) = (self.grads.lm.as_mut(), gring.data) {
                for (w, g) in data.into_iter().enumerate() {
                    home[w].axpy(scale, &g);
                }
            }
        }
        for t in dlogits {
            self.ctx.free(t);
        }

        // final LN backward
        let mut dx = Vec::with_capacity(n);
        for w in 0..n {
            let rep = self.rep.as_ref().map(|r| &r[w]);
            let g = rep.map(|r| r.lnf_g.clone());
            let mut outs = self.ctx.call_op(
                w,
                Op::LnBwd,
                b,
                n,
                &[
                    x[w].buf.arg(),
                    arg_of(g.as_ref()),
                    dxf[w].buf.arg(),
                ],
                &[acts, MemCategory::Grads, MemCategory::Grads],
            )?;
            let db = outs.pop().unwrap();
            let dg = outs.pop().unwrap();
            let d = outs.pop().unwrap();
            if let Some(gr) = self.g_rep.as_mut() {
                gr[w].lnf_g.add_assign(dg.f());
                gr[w].lnf_b.add_assign(db.f());
            }
            self.ctx.free(db);
            self.ctx.free(dg);
            dx.push(d);
        }
        for t in dxf {
            self.ctx.free(t);
        }
        for t in xf {
            self.ctx.free(t);
        }
        for t in x {
            self.ctx.free(t);
        }

        for l in (0..cfg.layers).rev() {
            let SavedRtp { x_in, a, x_mid, m, probs, gates } = saved.pop().unwrap();

            // b2 grads (replicated)
            if let Some(gr) = self.g_rep.as_mut() {
                for w in 0..n {
                    gr[w].layers[l].b2.add_assign(&dx[w].f().sum_leading());
                }
            }

            // mlp/moe backward rotation
            let mut dm: Vec<TBuf> = Vec::with_capacity(n);
            for w in 0..n {
                dm.push(self.ctx.alloc(w, acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?);
            }
            let mut dgates: Vec<Vec<(usize, HostTensor)>> = (0..n).map(|_| Vec::new()).collect();
            {
                let mut gring: Ring<MlpShardV> = Ring {
                    ids: self.rings.mlp[l].ids.clone(),
                    data: self.rings.mlp[l]
                        .data
                        .as_ref()
                        .map(|d| d.iter().map(zero_like_mlp).collect()),
                };
                for t in 0..n {
                    let tok = if t + 1 < n {
                        Self::oop_prefetch(&mut self.ctx, variant, self.bytes.mlp, false)
                    } else {
                        None
                    };
                    for w in 0..n {
                        let sid = self.rings.mlp[l].id(w);
                        if !cfg.is_moe() {
                            let sh = self.rings.mlp[l].get(w).map(|s| match s {
                                MlpShardV::Dense(d) => d,
                                _ => unreachable!(),
                            });
                            let mut outs = self.ctx.call_op(
                                w,
                                Op::MlpBwd,
                                b,
                                n,
                                &[
                                    m[w].buf.arg(),
                                    arg_of(sh.map(|s| &s.w1)),
                                    arg_of(sh.map(|s| &s.b1)),
                                    arg_of(sh.map(|s| &s.w2)),
                                    dx[w].buf.arg(),
                                ],
                                &[
                                    acts,
                                    MemCategory::Grads,
                                    MemCategory::Grads,
                                    MemCategory::Grads,
                                ],
                            )?;
                            let dw2 = outs.pop().unwrap();
                            let db1 = outs.pop().unwrap();
                            let dw1 = outs.pop().unwrap();
                            let d = outs.pop().unwrap();
                            if let Some(MlpShardV::Dense(g)) = gring.get_mut(w) {
                                g.w2.add_assign(dw2.f());
                                g.b1.add_assign(db1.f());
                                g.w1.add_assign(dw1.f());
                            }
                            self.ctx.accumulate(&mut dm[w], &d);
                            self.ctx.free(d);
                            self.ctx.free(dw2);
                            self.ctx.free(db1);
                            self.ctx.free(dw1);
                        } else {
                            let per = cfg.experts / n;
                            for k in 0..per {
                                let e_global = sid * per + k;
                                let ex = self.rings.mlp[l].get(w).map(|s| match s {
                                    MlpShardV::Experts(ex) => &ex[k],
                                    _ => unreachable!(),
                                });
                                let mut outs = self.ctx.call_op(
                                    w,
                                    Op::MoeBwd,
                                    b,
                                    n,
                                    &[
                                        m[w].buf.arg(),
                                        gates[w][e_global].buf.arg(),
                                        arg_of(ex.map(|x| &x.w1)),
                                        arg_of(ex.map(|x| &x.b1)),
                                        arg_of(ex.map(|x| &x.w2)),
                                        dx[w].buf.arg(),
                                    ],
                                    &[
                                        acts,
                                        acts,
                                        MemCategory::Grads,
                                        MemCategory::Grads,
                                        MemCategory::Grads,
                                    ],
                                )?;
                                let dw2 = outs.pop().unwrap();
                                let db1 = outs.pop().unwrap();
                                let dw1 = outs.pop().unwrap();
                                let dgate = outs.pop().unwrap();
                                let d = outs.pop().unwrap();
                                if let Some(MlpShardV::Experts(g)) = gring.get_mut(w) {
                                    g[k].w2.add_assign(dw2.f());
                                    g[k].b1.add_assign(db1.f());
                                    g[k].w1.add_assign(dw1.f());
                                }
                                if !virt {
                                    dgates[w].push((e_global, dgate.f().clone()));
                                }
                                self.ctx.accumulate(&mut dm[w], &d);
                                self.ctx.free(d);
                                self.ctx.free(dgate);
                                self.ctx.free(dw2);
                                self.ctx.free(db1);
                                self.ctx.free(dw1);
                            }
                        }
                        self.ctx.trace(TraceEvent::Compute {
                            worker: w,
                            unit: format!("mlp.l{l}.bwd"),
                            shard: sid,
                            step: t,
                        });
                    }
                    if t + 1 < n {
                        Self::oop_wait(&mut self.ctx, tok);
                        Self::rotate(
                            &mut self.ctx,
                            variant,
                            &mut self.rings.mlp[l],
                            Some(&mut gring),
                            self.bytes.mlp,
                            false,
                            t,
                        );
                    }
                }
                if let (Some(home), Some(data)) =
                    (self.grads.mlp.as_mut(), gring.data)
                {
                    for (w, g) in data.into_iter().enumerate() {
                        match (&mut home[l][w], g) {
                            (MlpShardV::Dense(hd), MlpShardV::Dense(gd)) => {
                                hd.w1.axpy(scale, &gd.w1);
                                hd.b1.axpy(scale, &gd.b1);
                                hd.w2.axpy(scale, &gd.w2);
                            }
                            (MlpShardV::Experts(hx), MlpShardV::Experts(gx)) => {
                                for (hk, gk) in hx.iter_mut().zip(gx) {
                                    hk.w1.axpy(scale, &gk.w1);
                                    hk.b1.axpy(scale, &gk.b1);
                                    hk.w2.axpy(scale, &gk.w2);
                                }
                            }
                            _ => unreachable!(),
                        }
                    }
                }
            }

            // MoE router backward (replicated)
            if cfg.is_moe() {
                for w in 0..n {
                    let dprobs_buf = if virt {
                        Buf::Virt(vec![b, cfg.seq, cfg.experts])
                    } else {
                        Buf::Real(scatter_dgates(&dgates[w], probs[w].f()))
                    };
                    let dprobs = self.ctx.alloc(w, acts, dprobs_buf)?;
                    let rep = self.rep.as_ref().map(|r| &r[w].layers[l]);
                    let wr = rep.and_then(|r| r.wr.clone());
                    let mut outs = self.ctx.call_op(
                        w,
                        Op::RouterBwd,
                        b,
                        n,
                        &[m[w].buf.arg(), arg_of(wr.as_ref()), dprobs.buf.arg()],
                        &[acts, MemCategory::Grads],
                    )?;
                    let dwr = outs.pop().unwrap();
                    let d = outs.pop().unwrap();
                    if let Some(gr) = self.g_rep.as_mut() {
                        if let Some(gwr) = gr[w].layers[l].wr.as_mut() {
                            gwr.add_assign(dwr.f());
                        }
                    }
                    self.ctx.accumulate(&mut dm[w], &d);
                    self.ctx.free(d);
                    self.ctx.free(dwr);
                    self.ctx.free(dprobs);
                }
            }
            for p in probs {
                self.ctx.free(p);
            }
            for gw in gates {
                for g in gw {
                    self.ctx.free(g);
                }
            }

            // ln2 backward + residual
            for w in 0..n {
                let rep = self.rep.as_ref().map(|r| &r[w].layers[l]);
                let g = rep.map(|r| r.ln2_g.clone());
                let mut outs = self.ctx.call_op(
                    w,
                    Op::LnBwd,
                    b,
                    n,
                    &[
                        x_mid[w].buf.arg(),
                        arg_of(g.as_ref()),
                        dm[w].buf.arg(),
                    ],
                    &[acts, MemCategory::Grads, MemCategory::Grads],
                )?;
                let db = outs.pop().unwrap();
                let dg = outs.pop().unwrap();
                let dxl = outs.pop().unwrap();
                if let Some(gr) = self.g_rep.as_mut() {
                    gr[w].layers[l].ln2_g.add_assign(dg.f());
                    gr[w].layers[l].ln2_b.add_assign(db.f());
                }
                self.ctx.free(db);
                self.ctx.free(dg);
                self.ctx.accumulate(&mut dx[w], &dxl);
                self.ctx.free(dxl);
            }
            for t in dm {
                self.ctx.free(t);
            }
            for t in m {
                self.ctx.free(t);
            }
            for t in x_mid {
                self.ctx.free(t);
            }

            // bo grads + attention backward rotation
            if let Some(gr) = self.g_rep.as_mut() {
                for w in 0..n {
                    gr[w].layers[l].bo.add_assign(&dx[w].f().sum_leading());
                }
            }
            let mut da: Vec<TBuf> = Vec::with_capacity(n);
            for w in 0..n {
                da.push(self.ctx.alloc(w, acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?);
            }
            {
                let mut gring: Ring<AttnShard> = Ring {
                    ids: self.rings.attn[l].ids.clone(),
                    data: self.rings.attn[l]
                        .data
                        .as_ref()
                        .map(|d| d.iter().map(zero_like_attn).collect()),
                };
                for t in 0..n {
                    let tok = if t + 1 < n {
                        Self::oop_prefetch(&mut self.ctx, variant, self.bytes.attn, false)
                    } else {
                        None
                    };
                    for w in 0..n {
                        let sid = self.rings.attn[l].id(w);
                        let sh = self.rings.attn[l].get(w);
                        let mut outs = self.ctx.call_op(
                            w,
                            Op::AttnBwd,
                            b,
                            n,
                            &[
                                a[w].buf.arg(),
                                arg_of(sh.map(|s| &s.wqkv)),
                                arg_of(sh.map(|s| &s.bqkv)),
                                arg_of(sh.map(|s| &s.wo)),
                                dx[w].buf.arg(),
                            ],
                            &[
                                acts,
                                MemCategory::Grads,
                                MemCategory::Grads,
                                MemCategory::Grads,
                            ],
                        )?;
                        let dwo = outs.pop().unwrap();
                        let dbq = outs.pop().unwrap();
                        let dwq = outs.pop().unwrap();
                        let d = outs.pop().unwrap();
                        if let Some(g) = gring.get_mut(w) {
                            g.wo.add_assign(dwo.f());
                            g.bqkv.add_assign(dbq.f());
                            g.wqkv.add_assign(dwq.f());
                        }
                        self.ctx.accumulate(&mut da[w], &d);
                        self.ctx.free(d);
                        self.ctx.free(dwo);
                        self.ctx.free(dbq);
                        self.ctx.free(dwq);
                        self.ctx.trace(TraceEvent::Compute {
                            worker: w,
                            unit: format!("attn.l{l}.bwd"),
                            shard: sid,
                            step: t,
                        });
                    }
                    if t + 1 < n {
                        Self::oop_wait(&mut self.ctx, tok);
                        Self::rotate(
                            &mut self.ctx,
                            variant,
                            &mut self.rings.attn[l],
                            Some(&mut gring),
                            self.bytes.attn,
                            false,
                            t,
                        );
                    }
                }
                if let (Some(home), Some(data)) = (self.grads.attn.as_mut(), gring.data) {
                    for (w, g) in data.into_iter().enumerate() {
                        home[l][w].wqkv.axpy(scale, &g.wqkv);
                        home[l][w].bqkv.axpy(scale, &g.bqkv);
                        home[l][w].wo.axpy(scale, &g.wo);
                    }
                }
            }

            // ln1 backward
            for w in 0..n {
                let rep = self.rep.as_ref().map(|r| &r[w].layers[l]);
                let g = rep.map(|r| r.ln1_g.clone());
                let mut outs = self.ctx.call_op(
                    w,
                    Op::LnBwd,
                    b,
                    n,
                    &[
                        x_in[w].buf.arg(),
                        arg_of(g.as_ref()),
                        da[w].buf.arg(),
                    ],
                    &[acts, MemCategory::Grads, MemCategory::Grads],
                )?;
                let db = outs.pop().unwrap();
                let dg = outs.pop().unwrap();
                let dxl = outs.pop().unwrap();
                if let Some(gr) = self.g_rep.as_mut() {
                    gr[w].layers[l].ln1_g.add_assign(dg.f());
                    gr[w].layers[l].ln1_b.add_assign(db.f());
                }
                self.ctx.free(db);
                self.ctx.free(dg);
                self.ctx.accumulate(&mut dx[w], &dxl);
                self.ctx.free(dxl);
            }
            for t in da {
                self.ctx.free(t);
            }
            for t in a {
                self.ctx.free(t);
            }
            for t in x_in {
                self.ctx.free(t);
            }
        }

        // embedding backward rotation (ring is at its post-forward
        // position, counter-rotates home)
        {
            let mut gring: Ring<EmbShard> = Ring {
                ids: self.rings.emb.ids.clone(),
                data: self.rings.emb.data.as_ref().map(|d| {
                    d.iter()
                        .map(|e| EmbShard {
                            wte: HostTensor::zeros(&e.wte.shape),
                            wpe: HostTensor::zeros(&e.wpe.shape),
                        })
                        .collect()
                }),
            };
            for t in 0..n {
                let tok = if t + 1 < n {
                    Self::oop_prefetch(&mut self.ctx, variant, self.bytes.emb, false)
                } else {
                    None
                };
                for w in 0..n {
                    let sid = self.rings.emb.id(w);
                    let dx_w = self.ctx.col_slice(w, &dx[w], sid * hp, hp, acts)?;
                    let mut outs = self.ctx.call_op(
                        w,
                        Op::EmbBwd,
                        b,
                        n,
                        &[ids[w].buf.arg(), dx_w.buf.arg()],
                        &[MemCategory::Grads, MemCategory::Grads],
                    )?;
                    let dwpe = outs.pop().unwrap();
                    let dwte = outs.pop().unwrap();
                    if let Some(g) = gring.get_mut(w) {
                        g.wte.add_assign(dwte.f());
                        g.wpe.add_assign(dwpe.f());
                    }
                    self.ctx.free(dwte);
                    self.ctx.free(dwpe);
                    self.ctx.free(dx_w);
                    self.ctx.trace(TraceEvent::Compute {
                        worker: w,
                        unit: "emb.bwd".to_string(),
                        shard: sid,
                        step: t,
                    });
                }
                if t + 1 < n {
                    Self::oop_wait(&mut self.ctx, tok);
                    Self::rotate(
                        &mut self.ctx,
                        variant,
                        &mut self.rings.emb,
                        Some(&mut gring),
                        self.bytes.emb,
                        false,
                        t,
                    );
                }
            }
            if let (Some(home), Some(data)) = (self.grads.emb.as_mut(), gring.data) {
                for (w, g) in data.into_iter().enumerate() {
                    home[w].wte.axpy(scale, &g.wte);
                    home[w].wpe.axpy(scale, &g.wpe);
                }
            }
        }
        for t in dx {
            self.ctx.free(t);
        }
        for t in ids {
            self.ctx.free(t);
        }

        // replicated grads: one small allreduce replaces nothing the paper
        // counts (LNs + biases + router), but we charge it honestly —
        // 2(N-1) ring hops through the rank-local ports
        if n > 1 {
            let rep_bytes = (replicated_elems(&cfg) * 4) as u64;
            self.ctx
                .charge_comm("ar-replicated", CommPrim::AllReduce, rep_bytes);
            if let Some(gr) = self.g_rep.as_mut() {
                // allreduce-MEAN: idempotent on values that earlier steps
                // already reduced, so grads accumulate correctly across
                // steps without zeroing.
                let ports = self.ctx.cluster.ports();
                let mut flats: Vec<Vec<f32>> = gr.iter().map(|r| r.pack()).collect();
                crate::comm::allreduce_sum(ports, &mut flats);
                for (r, f) in gr.iter_mut().zip(&flats) {
                    r.unpack(f);
                    r.visit_mut(&mut |t| t.scale(scale));
                }
            }
        }
        if let Some(tl) = self.ctx.timeline.as_mut() {
            tl.barrier();
        }
        debug_assert_eq!(
            self.ctx.cluster.fabric().in_flight(),
            0,
            "rtp step left ring-fabric messages in flight"
        );

        // every ring must be home again — the paper's Fig-1 invariant
        for (l, r) in self.rings.attn.iter().enumerate() {
            debug_assert_eq!(r.ids, (0..n).collect::<Vec<_>>(), "attn ring {l} not home");
        }
        debug_assert_eq!(self.rings.emb.ids, (0..n).collect::<Vec<_>>());
        debug_assert_eq!(self.rings.lm.ids, (0..n).collect::<Vec<_>>());

        self.last_loss = loss_sum / n as f32;
        Ok(self.last_loss)
    }

    fn gather_params(&self) -> ModelParams {
        let cfg = &self.ctx.cfg;
        let _n = self.ctx.n();
        let heads = cfg.heads;
        let hd = cfg.head_dim();
        let mut out = ModelParams::zeros_like(cfg);
        // rings are home after a step (ids[w] == w)
        let by_id = |ring: &Ring<EmbShard>| -> Vec<EmbShard> {
            let mut v: Vec<(usize, EmbShard)> = ring
                .ids
                .iter()
                .zip(ring.data.as_ref().expect("virtual mode"))
                .map(|(&i, d)| (i, d.clone()))
                .collect();
            v.sort_by_key(|(i, _)| *i);
            v.into_iter().map(|(_, d)| d).collect()
        };
        let emb = by_id(&self.rings.emb);
        out.wte = partition::unshard_cols(&emb.iter().map(|e| e.wte.clone()).collect::<Vec<_>>());
        out.wpe = partition::unshard_cols(&emb.iter().map(|e| e.wpe.clone()).collect::<Vec<_>>());
        for (l, lp) in out.layers.iter_mut().enumerate() {
            let ring = &self.rings.attn[l];
            let mut shards: Vec<(usize, AttnShard)> = ring
                .ids
                .iter()
                .zip(ring.data.as_ref().expect("virtual mode"))
                .map(|(&i, d)| (i, d.clone()))
                .collect();
            shards.sort_by_key(|(i, _)| *i);
            let attn: Vec<AttnShard> = shards.into_iter().map(|(_, d)| d).collect();
            lp.wqkv = partition::unshard_qkv_cols(
                &attn.iter().map(|a| a.wqkv.clone()).collect::<Vec<_>>(),
                heads,
                hd,
            );
            lp.bqkv = partition::unshard_qkv_cols(
                &attn.iter().map(|a| a.bqkv.clone()).collect::<Vec<_>>(),
                heads,
                hd,
            );
            lp.wo = partition::unshard_rows(
                &attn.iter().map(|a| a.wo.clone()).collect::<Vec<_>>(),
            );
            let mring = &self.rings.mlp[l];
            let mut mshards: Vec<(usize, MlpShardV)> = mring
                .ids
                .iter()
                .zip(mring.data.as_ref().expect("virtual mode"))
                .map(|(&i, d)| (i, d.clone()))
                .collect();
            mshards.sort_by_key(|(i, _)| *i);
            let rep = &self.rep.as_ref().expect("virtual mode")[0].layers[l];
            lp.mlp = match &mshards[0].1 {
                MlpShardV::Dense(_) => {
                    let ms: Vec<MlpShard> = mshards
                        .into_iter()
                        .map(|(_, v)| match v {
                            MlpShardV::Dense(d) => d,
                            _ => unreachable!(),
                        })
                        .collect();
                    MlpParams::Dense {
                        w1: partition::unshard_cols(
                            &ms.iter().map(|m| m.w1.clone()).collect::<Vec<_>>(),
                        ),
                        b1: partition::unshard_cols(
                            &ms.iter().map(|m| m.b1.clone()).collect::<Vec<_>>(),
                        ),
                        w2: partition::unshard_rows(
                            &ms.iter().map(|m| m.w2.clone()).collect::<Vec<_>>(),
                        ),
                        b2: rep.b2.clone(),
                    }
                }
                MlpShardV::Experts(_) => {
                    let mut experts = Vec::new();
                    for (_, v) in mshards {
                        match v {
                            MlpShardV::Experts(ex) => experts.extend(ex),
                            _ => unreachable!(),
                        }
                    }
                    MlpParams::Moe {
                        wr: rep.wr.clone().expect("moe router"),
                        experts,
                        b2: rep.b2.clone(),
                    }
                }
            };
            lp.ln1_g = rep.ln1_g.clone();
            lp.ln1_b = rep.ln1_b.clone();
            lp.bo = rep.bo.clone();
            lp.ln2_g = rep.ln2_g.clone();
            lp.ln2_b = rep.ln2_b.clone();
        }
        let rep = &self.rep.as_ref().expect("virtual mode")[0];
        out.lnf_g = rep.lnf_g.clone();
        out.lnf_b = rep.lnf_b.clone();
        let mut lm: Vec<(usize, HostTensor)> = self
            .rings
            .lm
            .ids
            .iter()
            .zip(self.rings.lm.data.as_ref().expect("virtual mode"))
            .map(|(&i, d)| (i, d.clone()))
            .collect();
        lm.sort_by_key(|(i, _)| *i);
        out.wlm =
            partition::unshard_cols(&lm.into_iter().map(|(_, d)| d).collect::<Vec<_>>());
        out
    }

    fn gather_grads(&self) -> ModelParams {
        let cfg = &self.ctx.cfg;
        let heads = cfg.heads;
        let hd = cfg.head_dim();
        let mut out = ModelParams::zeros_like(cfg);
        let emb = self.grads.emb.as_ref().expect("virtual mode");
        out.wte = partition::unshard_cols(&emb.iter().map(|e| e.wte.clone()).collect::<Vec<_>>());
        out.wpe = partition::unshard_cols(&emb.iter().map(|e| e.wpe.clone()).collect::<Vec<_>>());
        let gattn = self.grads.attn.as_ref().expect("virtual mode");
        let gmlp = self.grads.mlp.as_ref().expect("virtual mode");
        let grep = self.g_rep.as_ref().expect("virtual mode");
        for (l, lp) in out.layers.iter_mut().enumerate() {
            lp.wqkv = partition::unshard_qkv_cols(
                &gattn[l].iter().map(|a| a.wqkv.clone()).collect::<Vec<_>>(),
                heads,
                hd,
            );
            lp.bqkv = partition::unshard_qkv_cols(
                &gattn[l].iter().map(|a| a.bqkv.clone()).collect::<Vec<_>>(),
                heads,
                hd,
            );
            lp.wo = partition::unshard_rows(
                &gattn[l].iter().map(|a| a.wo.clone()).collect::<Vec<_>>(),
            );
            let rep = &grep[0].layers[l];
            lp.mlp = match &gmlp[l][0] {
                MlpShardV::Dense(_) => {
                    let ms: Vec<&MlpShard> = gmlp[l]
                        .iter()
                        .map(|v| match v {
                            MlpShardV::Dense(d) => d,
                            _ => unreachable!(),
                        })
                        .collect();
                    MlpParams::Dense {
                        w1: partition::unshard_cols(
                            &ms.iter().map(|m| m.w1.clone()).collect::<Vec<_>>(),
                        ),
                        b1: partition::unshard_cols(
                            &ms.iter().map(|m| m.b1.clone()).collect::<Vec<_>>(),
                        ),
                        w2: partition::unshard_rows(
                            &ms.iter().map(|m| m.w2.clone()).collect::<Vec<_>>(),
                        ),
                        b2: rep.b2.clone(),
                    }
                }
                MlpShardV::Experts(_) => {
                    let mut experts = Vec::new();
                    for v in &gmlp[l] {
                        match v {
                            MlpShardV::Experts(ex) => experts.extend(ex.clone()),
                            _ => unreachable!(),
                        }
                    }
                    MlpParams::Moe {
                        wr: rep.wr.clone().expect("moe router"),
                        experts,
                        b2: rep.b2.clone(),
                    }
                }
            };
            lp.ln1_g = rep.ln1_g.clone();
            lp.ln1_b = rep.ln1_b.clone();
            lp.bo = rep.bo.clone();
            lp.ln2_g = rep.ln2_g.clone();
            lp.ln2_b = rep.ln2_b.clone();
        }
        out.lnf_g = grep[0].lnf_g.clone();
        out.lnf_b = grep[0].lnf_b.clone();
        out.wlm = partition::unshard_cols(self.grads.lm.as_ref().expect("virtual mode"));
        out
    }

    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor)) {
        // weights are home after a full step: ring slot w holds shard w
        let (Some(wd), Some(gd)) = (self.rings.emb.data.as_mut(), self.grads.emb.as_ref())
        else {
            return;
        };
        for (p, g) in wd.iter_mut().zip(gd) {
            f(&mut p.wte, &g.wte);
            f(&mut p.wpe, &g.wpe);
        }
        for (l, ring) in self.rings.attn.iter_mut().enumerate() {
            let gl = &self.grads.attn.as_ref().unwrap()[l];
            for (p, g) in ring.data.as_mut().unwrap().iter_mut().zip(gl) {
                f(&mut p.wqkv, &g.wqkv);
                f(&mut p.bqkv, &g.bqkv);
                f(&mut p.wo, &g.wo);
            }
        }
        for (l, ring) in self.rings.mlp.iter_mut().enumerate() {
            let gl = &self.grads.mlp.as_ref().unwrap()[l];
            for (p, g) in ring.data.as_mut().unwrap().iter_mut().zip(gl) {
                match (p, g) {
                    (MlpShardV::Dense(pd), MlpShardV::Dense(gd)) => {
                        f(&mut pd.w1, &gd.w1);
                        f(&mut pd.b1, &gd.b1);
                        f(&mut pd.w2, &gd.w2);
                    }
                    (MlpShardV::Experts(px), MlpShardV::Experts(gx)) => {
                        for (pe, ge) in px.iter_mut().zip(gx) {
                            f(&mut pe.w1, &ge.w1);
                            f(&mut pe.b1, &ge.b1);
                            f(&mut pe.w2, &ge.w2);
                        }
                    }
                    _ => unreachable!(),
                }
            }
        }
        for (p, g) in self
            .rings
            .lm
            .data
            .as_mut()
            .unwrap()
            .iter_mut()
            .zip(self.grads.lm.as_ref().unwrap())
        {
            f(p, g);
        }
        // replicated params: identical update on every worker's copy
        let grep = self.g_rep.as_ref().unwrap();
        for (p, g) in self.rep.as_mut().unwrap().iter_mut().zip(grep) {
            let mut gs: Vec<*const HostTensor> = Vec::new();
            g.visit(&mut |t| gs.push(t));
            let mut i = 0;
            p.visit_mut(&mut |t| {
                // SAFETY: parallel traversal of structurally-equal trees
                f(t, unsafe { &*gs[i] });
                i += 1;
            });
        }
    }

    fn zero_grads(&mut self) {
        if let Some(e) = self.grads.emb.as_mut() {
            for g in e {
                g.wte.data.fill(0.0);
                g.wpe.data.fill(0.0);
            }
        }
        if let Some(a) = self.grads.attn.as_mut() {
            for gl in a {
                for g in gl {
                    g.wqkv.data.fill(0.0);
                    g.bqkv.data.fill(0.0);
                    g.wo.data.fill(0.0);
                }
            }
        }
        if let Some(ms) = self.grads.mlp.as_mut() {
            for gl in ms {
                for g in gl {
                    match g {
                        MlpShardV::Dense(d) => {
                            d.w1.data.fill(0.0);
                            d.b1.data.fill(0.0);
                            d.w2.data.fill(0.0);
                        }
                        MlpShardV::Experts(ex) => {
                            for e in ex {
                                e.w1.data.fill(0.0);
                                e.b1.data.fill(0.0);
                                e.w2.data.fill(0.0);
                            }
                        }
                    }
                }
            }
        }
        if let Some(lm) = self.grads.lm.as_mut() {
            for g in lm {
                g.data.fill(0.0);
            }
        }
        if let Some(gr) = self.g_rep.as_mut() {
            for g in gr {
                g.visit_mut(&mut |t| t.data.fill(0.0));
            }
        }
    }

    fn ctx(&self) -> &Ctx {
        &self.ctx
    }
    fn ctx_mut(&mut self) -> &mut Ctx {
        &mut self.ctx
    }
}

// keep `shard_at` linked for the schedule tests even though the rings
// track positions directly
#[allow(dead_code)]
fn schedule_check(n: usize) -> bool {
    (0..n).all(|w| shard_at(RotationDir::Clockwise, w, 0, n) == w)
}
