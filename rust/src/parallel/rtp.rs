//! Rotated Tensor Parallelism — the paper's contribution (§3, §4).
//!
//! Both activations (batch dimension) and parameters are sharded; no
//! worker ever holds more than one shard of a unit. During forward, each
//! unit's shards rotate CLOCKWISE around the ring between the N partition
//! compute steps; during backward they rotate COUNTER-CLOCKWISE together
//! with a traveling gradient buffer, so after N-1 steps every weight
//! shard is back home and its gradient has accumulated every worker's
//! batch contribution — replacing DDP's allreduce entirely.
//!
//! Each rank is an independent [`RankEngine`]: it holds exactly ONE shard
//! of every unit (the one currently visiting), pushes it out of its own
//! `RingPort` at each rotation boundary and pulls its upstream neighbor's
//! in — the paper's §3.4 per-rank overlap of partition compute with
//! neighbor-only weight movement, expressed as a per-rank program rather
//! than modeled from a god-view loop. Shard ids ride the fabric in
//! virtual mode, so the per-hop schedule (and its trace) is
//! mode-independent.
//!
//! Variants (paper §3):
//! - **In-place**: rotation is blocking and reuses the live shard buffer —
//!   zero extra memory (Table 1 row `RTP Inplace`), serialized comm.
//! - **Out-of-place**: a persistent per-rank rotation buffer
//!   (`max(W,G)/N` — Table 1 row `RTP`) double-buffers the in-flight
//!   shard so rotation overlaps compute on a second stream; with
//!   `recycle` (§3.4.4) the buffer's bytes are repurposed for the
//!   logits/loss activations between its forward TTL and the backward.
//!
//! Out-of-place rotation is TRULY asynchronous under the Thread launcher:
//! at the top of each partition-compute step the rank's
//! [`CommStream`](crate::comm::CommStream) eagerly enqueues the held
//! shard to the downstream neighbor (the weight payload is an `Arc`, so
//! the in-flight copy deduplicates against the tensors the compute is
//! reading — "computation and communication start simultaneously",
//! §3.4.3), and `rotate_finish` joins the hop at the boundary, where the
//! incoming shard is normally already waiting. Under Lockstep the same
//! calls degrade to the synchronous boundary hop, so both launchers stay
//! bit-identical. The traveling gradient of the backward pass is
//! accumulated DURING the step, so it always moves at the boundary (its
//! payload does not exist before the compute finishes) — the eager half
//! of a backward hop is the weight shard only, exactly the `max(W,G)/N`
//! in-flight budget the comm buffer models.
//!
//! Partition strategies (§3.2): Output-Partition (embedding, LM head —
//! merge = concat), Number-of-head-Partition (attention — merge = add),
//! Megatron-pair MLP (merge = add), Expert-Partition (MoE — rotation
//! replaces the all-to-all).

use std::any::Any;
use std::sync::Arc;

use anyhow::Result;

use crate::cluster::TraceEvent;
use crate::comm::{self, CollectiveStream, CommPrim, InFlight, RingPort, RotationDir};
use crate::config::ModelCfg;
use crate::memory::tracker::MemCategory;
use crate::model::ops::Op;
use crate::model::partition::{self, AttnShard, MlpShard};
use crate::model::{ExpertParams, MlpParams, ModelParams};
use crate::perfmodel::Token;
use crate::runtime::fault::FaultPhase;
use crate::runtime::{arg_of, Buf};
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

use super::bucket::GradBuckets;
use super::common::{
    allgather_tensor, replicated_elems, scatter_dgates, top1_gates, Batch, RankCtx,
    RepParams, TBuf,
};
use super::RankEngine;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RtpVariant {
    InPlace,
    OutOfPlace { recycle: bool },
}

impl RtpVariant {
    fn overlapped(&self) -> bool {
        matches!(self, RtpVariant::OutOfPlace { .. })
    }
}

// ---------------------------------------------------------------------------
// this rank's slot on a rotating ring
// ---------------------------------------------------------------------------

/// The weight shard currently visiting THIS rank on one unit's rotation
/// ring: `id` names the shard, `data` carries its tensors (None in
/// virtual mode). The payload is an `Arc` so an eagerly-issued rotation
/// hop and the compute that still reads the shard alias ONE copy of the
/// tensors — the in-flight message is the double buffer, with zero
/// duplication. Between steps every slot's `Arc` is unique again (the
/// upstream sender drops its handle when it installs its own incoming
/// shard), so the optimizer mutates in place via [`Arc::get_mut`].
#[derive(Debug)]
struct RingSlot<T> {
    id: usize,
    data: Option<Arc<T>>,
}

impl<T: Any + Send + Sync> RingSlot<T> {
    fn home(rank: usize, data: Option<T>) -> Self {
        RingSlot { id: rank, data: data.map(Arc::new) }
    }
}

/// A traveling gradient slot (backward pass): owned payload, accumulated
/// into DURING the step's compute, moved at the boundary — never eager,
/// because the message does not exist until the accumulation is done.
#[derive(Debug)]
struct GradSlot<T> {
    id: usize,
    data: Option<T>,
}

impl<T: Any + Send> GradSlot<T> {
    /// One synchronous rotation hop through this rank's port.
    fn rotate(&mut self, port: &RingPort, dir: RotationDir) {
        let n = port.n();
        if n <= 1 {
            return;
        }
        let w = port.rank();
        match self.data.take() {
            None => {
                port.send(dir.send_peer(w, n), self.id);
                self.id = port.recv(dir.recv_peer(w, n));
            }
            Some(d) => {
                port.send(dir.send_peer(w, n), (self.id, d));
                let (id, d2): (usize, T) = port.recv(dir.recv_peer(w, n));
                self.id = id;
                self.data = Some(d2);
            }
        }
    }
}

/// The wire form of one weight-shard rotation hop: bare shard id in
/// virtual mode, `(id, Arc<shard>)` in real mode — ids and data ride the
/// same message, so the schedule is identical in both modes.
enum RotMsg<T: Any + Send + Sync> {
    Virt(InFlight<usize>),
    Real(InFlight<(usize, Arc<T>)>),
}

/// An issued (possibly in-flight) rotation hop plus its modeled-timeline
/// token, joined by [`RtpRank::rotate_finish`] at the step boundary.
struct PendingRot<T: Any + Send + Sync> {
    tok: Option<Token>,
    msg: RotMsg<T>,
}

#[derive(Debug, Clone)]
pub(crate) struct EmbShard {
    pub(crate) wte: HostTensor,
    pub(crate) wpe: HostTensor,
}

#[derive(Debug, Clone)]
pub(crate) enum MlpShardV {
    Dense(MlpShard),
    /// Expert-Partition: a contiguous group of E/N whole experts.
    Experts(Vec<ExpertParams>),
}

struct Rings {
    emb: RingSlot<EmbShard>,
    attn: Vec<RingSlot<AttnShard>>,
    mlp: Vec<RingSlot<MlpShardV>>,
    lm: RingSlot<HostTensor>,
}

/// Home gradient storage for THIS rank's own shard (shard id == rank —
/// where every traveling gradient lands after its N-1 backward hops).
struct HomeGrads {
    emb: Option<EmbShard>,
    attn: Option<Vec<AttnShard>>,
    mlp: Option<Vec<MlpShardV>>,
    lm: Option<HostTensor>,
}

/// Per-unit rotation message sizes (the FlatParameter the ring moves).
#[derive(Debug, Clone, Copy)]
struct ShardBytes {
    emb: u64,
    attn: u64,
    mlp: u64,
    lm: u64,
}

impl ShardBytes {
    fn of(cfg: &ModelCfg, n: usize) -> ShardBytes {
        let (v, h, s, f) = (cfg.vocab, cfg.hidden, cfg.seq, cfg.ffn);
        let hp = h / n;
        let mlp = if cfg.is_moe() {
            let per = cfg.experts / n;
            per * (h * cfg.expert_ffn + cfg.expert_ffn + cfg.expert_ffn * h)
        } else {
            let fp = f / n;
            h * fp + fp + fp * h
        };
        ShardBytes {
            emb: ((v * hp + s * hp) * 4) as u64,
            attn: ((h * 3 * hp + 3 * hp + hp * h) * 4) as u64,
            mlp: (mlp * 4) as u64,
            lm: ((h * (v / n)) * 4) as u64,
        }
    }

    /// Total sharded bytes per worker = (W_sharded)/N.
    fn total(&self, layers: usize) -> u64 {
        self.emb + layers as u64 * (self.attn + self.mlp) + self.lm
    }
}

fn zero_like_attn(s: &AttnShard) -> AttnShard {
    AttnShard {
        wqkv: HostTensor::zeros(&s.wqkv.shape),
        bqkv: HostTensor::zeros(&s.bqkv.shape),
        wo: HostTensor::zeros(&s.wo.shape),
    }
}

fn zero_like_mlp(s: &MlpShardV) -> MlpShardV {
    match s {
        MlpShardV::Dense(m) => MlpShardV::Dense(MlpShard {
            w1: HostTensor::zeros(&m.w1.shape),
            b1: HostTensor::zeros(&m.b1.shape),
            w2: HostTensor::zeros(&m.w2.shape),
        }),
        MlpShardV::Experts(ex) => MlpShardV::Experts(
            ex.iter()
                .map(|e| ExpertParams {
                    w1: HostTensor::zeros(&e.w1.shape),
                    b1: HostTensor::zeros(&e.b1.shape),
                    w2: HostTensor::zeros(&e.w2.shape),
                })
                .collect(),
        ),
    }
}

fn zero_like_emb(e: &EmbShard) -> EmbShard {
    EmbShard {
        wte: HostTensor::zeros(&e.wte.shape),
        wpe: HostTensor::zeros(&e.wpe.shape),
    }
}

// ---------------------------------------------------------------------------
// the rank engine
// ---------------------------------------------------------------------------

pub struct RtpRank {
    rank: usize,
    n: usize,
    cfg: ModelCfg,
    pub variant: RtpVariant,
    rings: Rings,
    grads: HomeGrads,
    rep: Option<RepParams>,
    g_rep: Option<RepParams>,
    /// Out-of-place: the persistent rotation buffer.
    comm_buf: Option<TBuf>,
    bytes: ShardBytes,
    /// Reused flattening scratch for the per-step replicated-grad
    /// allreduce (zero steady-state allocations on that path too).
    rep_scratch: Vec<f32>,
    /// Background collective engine: the replicated-grad allreduce rides
    /// the per-rank comm thread under the Thread launcher.
    coll: Option<CollectiveStream>,
    /// Persistent per-bucket scratch for the size-targeted bucketed
    /// allreduce (`RankCtx::bucket_elems`; unused when monolithic).
    rep_buckets: GradBuckets,
}

impl RtpRank {
    pub fn new(ctx: &mut RankCtx, seed: u64, variant: RtpVariant) -> Result<Self> {
        let n = ctx.n();
        let rank = ctx.rank;
        let cfg = ctx.cfg.clone();
        let virt = ctx.virtual_mode();
        if cfg.is_moe() {
            assert_eq!(cfg.experts % n, 0, "experts must divide over workers");
        }

        let bytes = ShardBytes::of(&cfg, n);
        let (rings, grads, rep, g_rep) = if virt {
            (
                Rings {
                    emb: RingSlot::home(rank, None),
                    attn: (0..cfg.layers).map(|_| RingSlot::home(rank, None)).collect(),
                    mlp: (0..cfg.layers).map(|_| RingSlot::home(rank, None)).collect(),
                    lm: RingSlot::home(rank, None),
                },
                HomeGrads { emb: None, attn: None, mlp: None, lm: None },
                None,
                None,
            )
        } else {
            // every rank derives the same full model from the same seed
            // and keeps only its home shard
            let full = ModelParams::init(&cfg, &mut Rng::new(seed));
            let heads = cfg.heads;
            let hd = cfg.head_dim();
            let emb = EmbShard {
                wte: partition::shard_cols(&full.wte, rank, n),
                wpe: partition::shard_cols(&full.wpe, rank, n),
            };
            let attn: Vec<AttnShard> = full
                .layers
                .iter()
                .map(|lp| {
                    partition::attn_shard(&lp.wqkv, &lp.bqkv, &lp.wo, rank, n, heads, hd)
                })
                .collect();
            let mlp: Vec<MlpShardV> = full
                .layers
                .iter()
                .map(|lp| match &lp.mlp {
                    MlpParams::Dense { w1, b1, w2, .. } => {
                        MlpShardV::Dense(partition::mlp_shard(w1, b1, w2, rank, n))
                    }
                    MlpParams::Moe { experts, .. } => MlpShardV::Experts(
                        partition::expert_range(rank, n, cfg.experts)
                            .map(|e| experts[e].clone())
                            .collect(),
                    ),
                })
                .collect();
            let lm = partition::shard_cols(&full.wlm, rank, n);
            let grads = HomeGrads {
                emb: Some(zero_like_emb(&emb)),
                attn: Some(attn.iter().map(zero_like_attn).collect()),
                mlp: Some(mlp.iter().map(zero_like_mlp).collect()),
                lm: Some(HostTensor::zeros(&lm.shape)),
            };
            let rep = RepParams::from_full(&full);
            let g_rep = rep.zeros_like();
            (
                Rings {
                    emb: RingSlot::home(rank, Some(emb)),
                    attn: attn
                        .into_iter()
                        .map(|a| RingSlot::home(rank, Some(a)))
                        .collect(),
                    mlp: mlp
                        .into_iter()
                        .map(|m| RingSlot::home(rank, Some(m)))
                        .collect(),
                    lm: RingSlot::home(rank, Some(lm)),
                },
                grads,
                Some(rep),
                Some(g_rep),
            )
        };

        // persistent residency: weight shard + grad shard + replicated ×2
        let sharded = bytes.total(cfg.layers);
        let rep_bytes = (replicated_elems(&cfg) * 4) as u64;
        ctx.tracker.alloc(MemCategory::Weights, sharded + rep_bytes)?;
        ctx.tracker.alloc(MemCategory::Grads, sharded + rep_bytes)?;
        // out-of-place: one persistent rotation buffer, sized for the
        // largest in-flight message: max(W,G)/N per Table 1 (weights and
        // grads are equal-sized here, and backward moves both => the
        // buffer holds one unit's weight+grad shard pair).
        let mut comm_buf = None;
        if variant.overlapped() {
            let unit_max = bytes.emb.max(bytes.attn).max(bytes.mlp).max(bytes.lm);
            comm_buf = Some(ctx.alloc(
                MemCategory::CommBuf,
                Buf::Virt(vec![(2 * unit_max / 4) as usize]),
            )?);
        }

        Ok(RtpRank {
            rank,
            n,
            cfg,
            variant,
            rings,
            grads,
            rep,
            g_rep,
            comm_buf,
            bytes,
            rep_scratch: Vec::new(),
            coll: None,
            rep_buckets: GradBuckets::new(),
        })
    }

    /// Issue one weight-shard rotation hop at the TOP of a partition
    /// compute step. Out-of-place: charges the modeled eager async
    /// rotation AND, on the rank's comm stream, puts the held shard on
    /// the wire (a real background hop under the Thread launcher; a
    /// deferred synchronous hop under Lockstep). In-place: everything is
    /// deferred to [`RtpRank::rotate_finish`] (blocking boundary hop).
    /// `fwd` chooses direction; `bytes` is the per-rank message size
    /// (backward doubles it: weights + traveling grads).
    fn rotate_begin<T: Any + Send + Sync>(
        ctx: &mut RankCtx,
        variant: RtpVariant,
        ring: &RingSlot<T>,
        bytes: u64,
        fwd: bool,
    ) -> PendingRot<T> {
        ctx.fault_point(FaultPhase::RotationHop);
        let msg_bytes = if fwd { bytes } else { 2 * bytes };
        let tok = if variant.overlapped() {
            ctx.timeline
                .as_deref_mut()
                .map(|tl| tl.comm_async_eager("rotate", CommPrim::Rotation, msg_bytes))
        } else {
            None
        };
        let stream = ctx.comm_stream(variant.overlapped());
        let dir = if fwd { RotationDir::Clockwise } else { RotationDir::CounterClockwise };
        let msg = match ring.data.as_ref() {
            None => RotMsg::Virt(stream.begin(ring.id, dir)),
            Some(arc) => RotMsg::Real(stream.begin((ring.id, Arc::clone(arc)), dir)),
        };
        PendingRot { tok, msg }
    }

    /// Join a rotation hop at the step boundary: charge the blocking
    /// (in-place) or wait on the modeled async (out-of-place) timeline
    /// span, complete the wire exchange, install the incoming shard, and
    /// move the traveling gradient (backward) one hop.
    #[allow(clippy::too_many_arguments)]
    fn rotate_finish<T: Any + Send + Sync>(
        ctx: &mut RankCtx,
        variant: RtpVariant,
        ring: &mut RingSlot<T>,
        gring: Option<&mut GradSlot<T>>,
        pending: PendingRot<T>,
        bytes: u64,
        fwd: bool,
        step: usize,
    ) {
        let msg_bytes = if fwd { bytes } else { 2 * bytes };
        match variant {
            RtpVariant::InPlace => {
                if let Some(tl) = ctx.timeline.as_deref_mut() {
                    tl.comm_blocking("rotate", CommPrim::Rotation, msg_bytes);
                }
            }
            RtpVariant::OutOfPlace { .. } => {
                Self::oop_wait(ctx, pending.tok);
            }
        }
        let stream = ctx.comm_stream(variant.overlapped());
        match pending.msg {
            RotMsg::Virt(inflight) => {
                ring.id = stream.wait(inflight);
            }
            RotMsg::Real(inflight) => {
                let (id, data) = stream.wait(inflight);
                ring.id = id;
                // the old Arc drops here: its only live handle is now the
                // one in flight to (or already at) the downstream rank
                ring.data = Some(data);
            }
        }
        let dir = if fwd { RotationDir::Clockwise } else { RotationDir::CounterClockwise };
        if let Some(g) = gring {
            g.rotate(&ctx.port, dir);
        }
        if ctx.lead() {
            ctx.trace(TraceEvent::Rotate {
                dir: if fwd { "cw" } else { "ccw" },
                bytes_per_worker: msg_bytes,
                step,
            });
        }
    }

    fn oop_wait(ctx: &mut RankCtx, tok: Option<Token>) {
        if let (Some(tl), Some(tok)) = (ctx.timeline.as_deref_mut(), tok) {
            tl.wait(tok);
        }
    }
}

/// Landing scale: batch-sharded loss means are averaged over workers.
fn land_scale(n: usize) -> f32 {
    1.0 / n as f32
}

impl RankEngine for RtpRank {
    fn rank(&self) -> usize {
        self.rank
    }

    fn step_local(&mut self, ctx: &mut RankCtx, batch: &Batch) -> Result<f32> {
        let n = ctx.n();
        let w = self.rank;
        let cfg = self.cfg.clone();
        let b = batch.ids.shape[0] / n; // local batch
        let (h, v) = (cfg.hidden, cfg.vocab);
        let (hp, vp) = (h / n, v / n);
        let virt = ctx.virtual_mode();
        let acts = MemCategory::Activations;
        let variant = self.variant;
        ctx.phase("forward");

        // this rank's batch shard
        let shard = batch.shard(w, n);
        let mk = |t: &crate::tensor::IntTensor| {
            if virt { Buf::Virt(vec![b, cfg.seq]) } else { Buf::Ids(t.clone()) }
        };
        let ids = ctx.alloc(acts, mk(&shard.ids))?;
        let tgts = ctx.alloc(acts, mk(&shard.targets))?;

        // ---------------- forward ----------------
        ctx.fault_point(FaultPhase::Forward);
        // embedding: Output-Partition, this rank assembles the FULL
        // hidden locally across the N rotation steps (no activation comm!)
        let mut x = ctx.alloc(acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?;
        for t in 0..n {
            let pending = if t + 1 < n {
                Some(Self::rotate_begin(ctx, variant, &self.rings.emb, self.bytes.emb, true))
            } else {
                None
            };
            let sid = self.rings.emb.id;
            {
                let sh = self.rings.emb.data.as_ref();
                let mut outs = ctx.call_op(
                    Op::EmbFwd,
                    b,
                    n,
                    &[ids.buf.arg(), arg_of(sh.map(|s| &s.wte)), arg_of(sh.map(|s| &s.wpe))],
                    &[acts],
                )?;
                let part = outs.pop().unwrap();
                ctx.write_col_slice(&mut x, sid * hp, &part);
                ctx.free(part);
            }
            ctx.trace(TraceEvent::Compute {
                worker: w,
                unit: "emb".to_string(),
                shard: sid,
                step: t,
            });
            if let Some(p) = pending {
                Self::rotate_finish(ctx, variant, &mut self.rings.emb, None, p, self.bytes.emb, true, t);
            }
        }

        struct SavedRtp {
            x_in: TBuf,
            a: TBuf,
            x_mid: TBuf,
            m: TBuf,
            probs: Option<TBuf>,
            gates: Vec<TBuf>, // [expert]
        }
        let mut saved: Vec<SavedRtp> = Vec::new();

        for l in 0..cfg.layers {
            // ln1 (replicated)
            let a = {
                let rep = self.rep.as_ref().map(|r| &r.layers[l]);
                let mut outs = ctx.call_op(
                    Op::LnFwd,
                    b,
                    n,
                    &[
                        x.buf.arg(),
                        arg_of(rep.map(|r| &r.ln1_g)),
                        arg_of(rep.map(|r| &r.ln1_b)),
                    ],
                    &[acts],
                )?;
                outs.pop().unwrap()
            };
            // attention: rotation loop, sum-merge
            let mut acc = ctx.alloc(acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?;
            for t in 0..n {
                let pending = if t + 1 < n {
                    Some(Self::rotate_begin(
                        ctx,
                        variant,
                        &self.rings.attn[l],
                        self.bytes.attn,
                        true,
                    ))
                } else {
                    None
                };
                let sid = self.rings.attn[l].id;
                {
                    let sh = self.rings.attn[l].data.as_ref();
                    let mut outs = ctx.call_op(
                        Op::AttnFwd,
                        b,
                        n,
                        &[
                            a.buf.arg(),
                            arg_of(sh.map(|s| &s.wqkv)),
                            arg_of(sh.map(|s| &s.bqkv)),
                            arg_of(sh.map(|s| &s.wo)),
                        ],
                        &[acts],
                    )?;
                    let part = outs.pop().unwrap();
                    ctx.accumulate(&mut acc, &part);
                    ctx.free(part);
                }
                ctx.trace(TraceEvent::Compute {
                    worker: w,
                    unit: format!("attn.l{l}"),
                    shard: sid,
                    step: t,
                });
                if let Some(p) = pending {
                    Self::rotate_finish(
                        ctx,
                        variant,
                        &mut self.rings.attn[l],
                        None,
                        p,
                        self.bytes.attn,
                        true,
                        t,
                    );
                }
            }
            let x_mid = {
                let mut part = acc;
                let bo = self.rep.as_ref().map(|r| r.layers[l].bo.clone());
                ctx.add_bias(&mut part, bo.as_ref());
                ctx.residual(&mut part, &x);
                part
            };
            // ln2
            let m = {
                let rep = self.rep.as_ref().map(|r| &r.layers[l]);
                let mut outs = ctx.call_op(
                    Op::LnFwd,
                    b,
                    n,
                    &[
                        x_mid.buf.arg(),
                        arg_of(rep.map(|r| &r.ln2_g)),
                        arg_of(rep.map(|r| &r.ln2_b)),
                    ],
                    &[acts],
                )?;
                outs.pop().unwrap()
            };
            // mlp / moe: rotation loop, sum-merge
            let mut probs: Option<TBuf> = None;
            let mut gates: Vec<TBuf> = Vec::new();
            if cfg.is_moe() {
                // replicated router runs once on this rank
                let rep = self.rep.as_ref().map(|r| &r.layers[l]);
                let wr = rep.and_then(|r| r.wr.as_ref());
                let mut outs = ctx.call_op(
                    Op::RouterFwd,
                    b,
                    n,
                    &[m.buf.arg(), arg_of(wr)],
                    &[acts],
                )?;
                let p = outs.pop().unwrap();
                let gate_bufs: Vec<Buf> = if virt {
                    (0..cfg.experts).map(|_| Buf::Virt(vec![b, cfg.seq])).collect()
                } else {
                    top1_gates(p.f(), cfg.experts).into_iter().map(Buf::Real).collect()
                };
                for g in gate_bufs {
                    gates.push(ctx.alloc(acts, g)?);
                }
                probs = Some(p);
            }
            let mut acc = ctx.alloc(acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?;
            for t in 0..n {
                let pending = if t + 1 < n {
                    Some(Self::rotate_begin(
                        ctx,
                        variant,
                        &self.rings.mlp[l],
                        self.bytes.mlp,
                        true,
                    ))
                } else {
                    None
                };
                let sid = self.rings.mlp[l].id;
                if !cfg.is_moe() {
                    let sh = self.rings.mlp[l].data.as_ref().map(|s| match &**s {
                        MlpShardV::Dense(d) => d,
                        _ => unreachable!(),
                    });
                    let mut outs = ctx.call_op(
                        Op::MlpFwd,
                        b,
                        n,
                        &[
                            m.buf.arg(),
                            arg_of(sh.map(|s| &s.w1)),
                            arg_of(sh.map(|s| &s.b1)),
                            arg_of(sh.map(|s| &s.w2)),
                        ],
                        &[acts],
                    )?;
                    let part = outs.pop().unwrap();
                    ctx.accumulate(&mut acc, &part);
                    ctx.free(part);
                } else {
                    // every expert in the held group visits this rank
                    let per = cfg.experts / n;
                    for k in 0..per {
                        let e_global = sid * per + k;
                        let ex = self.rings.mlp[l].data.as_ref().map(|s| match &**s {
                            MlpShardV::Experts(ex) => &ex[k],
                            _ => unreachable!(),
                        });
                        let mut outs = ctx.call_op(
                            Op::MoeFwd,
                            b,
                            n,
                            &[
                                m.buf.arg(),
                                gates[e_global].buf.arg(),
                                arg_of(ex.map(|x| &x.w1)),
                                arg_of(ex.map(|x| &x.b1)),
                                arg_of(ex.map(|x| &x.w2)),
                            ],
                            &[acts],
                        )?;
                        let part = outs.pop().unwrap();
                        ctx.accumulate(&mut acc, &part);
                        ctx.free(part);
                    }
                }
                ctx.trace(TraceEvent::Compute {
                    worker: w,
                    unit: format!("mlp.l{l}"),
                    shard: sid,
                    step: t,
                });
                if let Some(p) = pending {
                    Self::rotate_finish(
                        ctx,
                        variant,
                        &mut self.rings.mlp[l],
                        None,
                        p,
                        self.bytes.mlp,
                        true,
                        t,
                    );
                }
            }
            let x_new = {
                let mut part = acc;
                let b2 = self.rep.as_ref().map(|r| r.layers[l].b2.clone());
                ctx.add_bias(&mut part, b2.as_ref());
                ctx.residual(&mut part, &x_mid);
                part
            };
            saved.push(SavedRtp { x_in: x, a, x_mid, m, probs, gates });
            x = x_new;
        }

        // final LN
        let xf = {
            let rep = self.rep.as_ref();
            let mut outs = ctx.call_op(
                Op::LnFwd,
                b,
                n,
                &[
                    x.buf.arg(),
                    arg_of(rep.map(|r| &r.lnf_g)),
                    arg_of(rep.map(|r| &r.lnf_b)),
                ],
                &[acts],
            )?;
            outs.pop().unwrap()
        };

        // LM head: Output-Partition; full local logits assembled over the
        // rotation steps
        let mut logits = ctx.alloc(acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, v]))?;
        for t in 0..n {
            let pending = if t + 1 < n {
                Some(Self::rotate_begin(ctx, variant, &self.rings.lm, self.bytes.lm, true))
            } else {
                None
            };
            let sid = self.rings.lm.id;
            {
                let sh = self.rings.lm.data.as_ref();
                let mut outs = ctx.call_op(
                    Op::LmheadFwd,
                    b,
                    n,
                    &[xf.buf.arg(), arg_of(sh.map(|s| &**s))],
                    &[acts],
                )?;
                let part = outs.pop().unwrap();
                ctx.write_col_slice(&mut logits, sid * vp, &part);
                ctx.free(part);
            }
            ctx.trace(TraceEvent::Compute {
                worker: w,
                unit: "lmhead".to_string(),
                shard: sid,
                step: t,
            });
            if let Some(p) = pending {
                Self::rotate_finish(ctx, variant, &mut self.rings.lm, None, p, self.bytes.lm, true, t);
            }
        }

        // §3.4.4 buffer recycling: the rotation buffer's TTL ended with the
        // last LM-head rotation; its bytes serve the loss activations.
        let recycle = matches!(variant, RtpVariant::OutOfPlace { recycle: true });
        if recycle {
            if let Some(tb) = self.comm_buf.as_ref() {
                ctx.recycle(tb, MemCategory::Activations);
            }
        }

        // loss
        ctx.phase("loss");
        let (loss, dlogits) = {
            let mut outs = ctx.call_op(
                Op::Xent,
                b,
                n,
                &[logits.buf.arg(), tgts.buf.arg()],
                &[acts, acts],
            )?;
            let dl = outs.pop().unwrap();
            let lb = outs.pop().unwrap();
            let loss = ctx.loss_of(&lb);
            ctx.free(lb);
            (loss, dl)
        };
        ctx.free(logits);
        ctx.free(tgts);
        if recycle {
            // backward rotations need the buffer again
            if let Some(tb) = self.comm_buf.as_ref() {
                ctx.recycle(tb, MemCategory::CommBuf);
            }
        }

        // ---------------- backward ----------------
        ctx.phase("backward");
        ctx.fault_point(FaultPhase::Backward);
        let scale = land_scale(n);

        // LM head backward: ccw rotation with traveling grads
        let mut dxf = ctx.alloc(acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?;
        {
            let mut gring: GradSlot<HostTensor> = GradSlot {
                id: self.rings.lm.id,
                data: self
                    .rings
                    .lm
                    .data
                    .as_ref()
                    .map(|t| HostTensor::zeros(&t.shape)),
            };
            for t in 0..n {
                let pending = if t + 1 < n {
                    Some(Self::rotate_begin(ctx, variant, &self.rings.lm, self.bytes.lm, false))
                } else {
                    None
                };
                let sid = self.rings.lm.id;
                {
                    let dl_w = ctx.col_slice(&dlogits, sid * vp, vp, acts)?;
                    let sh = self.rings.lm.data.as_ref();
                    let mut outs = ctx.call_op(
                        Op::LmheadBwd,
                        b,
                        n,
                        &[xf.buf.arg(), arg_of(sh.map(|s| &**s)), dl_w.buf.arg()],
                        &[acts, MemCategory::Grads],
                    )?;
                    let dwlm = outs.pop().unwrap();
                    let dx = outs.pop().unwrap();
                    if let Some(g) = gring.data.as_mut() {
                        g.add_assign(dwlm.f());
                    }
                    ctx.accumulate(&mut dxf, &dx);
                    ctx.free(dx);
                    ctx.free(dwlm);
                    ctx.free(dl_w);
                }
                ctx.trace(TraceEvent::Compute {
                    worker: w,
                    unit: "lmhead.bwd".to_string(),
                    shard: sid,
                    step: t,
                });
                if let Some(p) = pending {
                    Self::rotate_finish(
                        ctx,
                        variant,
                        &mut self.rings.lm,
                        Some(&mut gring),
                        p,
                        self.bytes.lm,
                        false,
                        t,
                    );
                }
            }
            // land home (id == rank now)
            debug_assert_eq!(gring.id, w, "lm gring not home");
            if let (Some(home), Some(g)) = (self.grads.lm.as_mut(), gring.data) {
                home.axpy(scale, &g);
            }
        }
        ctx.free(dlogits);

        // final LN backward
        let mut dx = {
            let rep = self.rep.as_ref();
            let g = rep.map(|r| r.lnf_g.clone());
            let mut outs = ctx.call_op(
                Op::LnBwd,
                b,
                n,
                &[x.buf.arg(), arg_of(g.as_ref()), dxf.buf.arg()],
                &[acts, MemCategory::Grads, MemCategory::Grads],
            )?;
            let db = outs.pop().unwrap();
            let dg = outs.pop().unwrap();
            let d = outs.pop().unwrap();
            if let Some(gr) = self.g_rep.as_mut() {
                gr.lnf_g.add_assign(dg.f());
                gr.lnf_b.add_assign(db.f());
            }
            ctx.free(db);
            ctx.free(dg);
            d
        };
        ctx.free(dxf);
        ctx.free(xf);
        ctx.free(x);

        for l in (0..cfg.layers).rev() {
            let SavedRtp { x_in, a, x_mid, m, probs, gates } = saved.pop().unwrap();

            // b2 grads (replicated)
            if let Some(gr) = self.g_rep.as_mut() {
                gr.layers[l].b2.add_assign(&dx.f().sum_leading());
            }

            // mlp/moe backward rotation
            let mut dm = ctx.alloc(acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?;
            let mut dgates: Vec<(usize, HostTensor)> = Vec::new();
            {
                let mut gring: GradSlot<MlpShardV> = GradSlot {
                    id: self.rings.mlp[l].id,
                    data: self.rings.mlp[l].data.as_ref().map(|s| zero_like_mlp(s)),
                };
                for t in 0..n {
                    let pending = if t + 1 < n {
                        Some(Self::rotate_begin(
                            ctx,
                            variant,
                            &self.rings.mlp[l],
                            self.bytes.mlp,
                            false,
                        ))
                    } else {
                        None
                    };
                    let sid = self.rings.mlp[l].id;
                    if !cfg.is_moe() {
                        let sh = self.rings.mlp[l].data.as_ref().map(|s| match &**s {
                            MlpShardV::Dense(d) => d,
                            _ => unreachable!(),
                        });
                        let mut outs = ctx.call_op(
                            Op::MlpBwd,
                            b,
                            n,
                            &[
                                m.buf.arg(),
                                arg_of(sh.map(|s| &s.w1)),
                                arg_of(sh.map(|s| &s.b1)),
                                arg_of(sh.map(|s| &s.w2)),
                                dx.buf.arg(),
                            ],
                            &[
                                acts,
                                MemCategory::Grads,
                                MemCategory::Grads,
                                MemCategory::Grads,
                            ],
                        )?;
                        let dw2 = outs.pop().unwrap();
                        let db1 = outs.pop().unwrap();
                        let dw1 = outs.pop().unwrap();
                        let d = outs.pop().unwrap();
                        if let Some(MlpShardV::Dense(g)) = gring.data.as_mut() {
                            g.w2.add_assign(dw2.f());
                            g.b1.add_assign(db1.f());
                            g.w1.add_assign(dw1.f());
                        }
                        ctx.accumulate(&mut dm, &d);
                        ctx.free(d);
                        ctx.free(dw2);
                        ctx.free(db1);
                        ctx.free(dw1);
                    } else {
                        let per = cfg.experts / n;
                        for k in 0..per {
                            let e_global = sid * per + k;
                            let ex = self.rings.mlp[l].data.as_ref().map(|s| match &**s {
                                MlpShardV::Experts(ex) => &ex[k],
                                _ => unreachable!(),
                            });
                            let mut outs = ctx.call_op(
                                Op::MoeBwd,
                                b,
                                n,
                                &[
                                    m.buf.arg(),
                                    gates[e_global].buf.arg(),
                                    arg_of(ex.map(|x| &x.w1)),
                                    arg_of(ex.map(|x| &x.b1)),
                                    arg_of(ex.map(|x| &x.w2)),
                                    dx.buf.arg(),
                                ],
                                &[
                                    acts,
                                    acts,
                                    MemCategory::Grads,
                                    MemCategory::Grads,
                                    MemCategory::Grads,
                                ],
                            )?;
                            let dw2 = outs.pop().unwrap();
                            let db1 = outs.pop().unwrap();
                            let dw1 = outs.pop().unwrap();
                            let dgate = outs.pop().unwrap();
                            let d = outs.pop().unwrap();
                            if let Some(MlpShardV::Experts(g)) = gring.data.as_mut() {
                                g[k].w2.add_assign(dw2.f());
                                g[k].b1.add_assign(db1.f());
                                g[k].w1.add_assign(dw1.f());
                            }
                            if !virt {
                                dgates.push((e_global, dgate.f().clone()));
                            }
                            ctx.accumulate(&mut dm, &d);
                            ctx.free(d);
                            ctx.free(dgate);
                            ctx.free(dw2);
                            ctx.free(db1);
                            ctx.free(dw1);
                        }
                    }
                    ctx.trace(TraceEvent::Compute {
                        worker: w,
                        unit: format!("mlp.l{l}.bwd"),
                        shard: sid,
                        step: t,
                    });
                    if let Some(p) = pending {
                        Self::rotate_finish(
                            ctx,
                            variant,
                            &mut self.rings.mlp[l],
                            Some(&mut gring),
                            p,
                            self.bytes.mlp,
                            false,
                            t,
                        );
                    }
                }
                debug_assert_eq!(gring.id, w, "mlp gring {l} not home");
                if let (Some(home), Some(g)) = (self.grads.mlp.as_mut(), gring.data) {
                    match (&mut home[l], g) {
                        (MlpShardV::Dense(hd), MlpShardV::Dense(gd)) => {
                            hd.w1.axpy(scale, &gd.w1);
                            hd.b1.axpy(scale, &gd.b1);
                            hd.w2.axpy(scale, &gd.w2);
                        }
                        (MlpShardV::Experts(hx), MlpShardV::Experts(gx)) => {
                            for (hk, gk) in hx.iter_mut().zip(gx) {
                                hk.w1.axpy(scale, &gk.w1);
                                hk.b1.axpy(scale, &gk.b1);
                                hk.w2.axpy(scale, &gk.w2);
                            }
                        }
                        _ => unreachable!(),
                    }
                }
            }

            // MoE router backward (replicated)
            if cfg.is_moe() {
                let probs_buf = probs.as_ref().expect("moe saved probs");
                let dprobs_buf = if virt {
                    Buf::Virt(vec![b, cfg.seq, cfg.experts])
                } else {
                    Buf::Real(scatter_dgates(&dgates, probs_buf.f()))
                };
                let dprobs = ctx.alloc(acts, dprobs_buf)?;
                let rep = self.rep.as_ref().map(|r| &r.layers[l]);
                let wr = rep.and_then(|r| r.wr.clone());
                let mut outs = ctx.call_op(
                    Op::RouterBwd,
                    b,
                    n,
                    &[m.buf.arg(), arg_of(wr.as_ref()), dprobs.buf.arg()],
                    &[acts, MemCategory::Grads],
                )?;
                let dwr = outs.pop().unwrap();
                let d = outs.pop().unwrap();
                if let Some(gr) = self.g_rep.as_mut() {
                    if let Some(gwr) = gr.layers[l].wr.as_mut() {
                        gwr.add_assign(dwr.f());
                    }
                }
                ctx.accumulate(&mut dm, &d);
                ctx.free(d);
                ctx.free(dwr);
                ctx.free(dprobs);
            }
            if let Some(p) = probs {
                ctx.free(p);
            }
            for g in gates {
                ctx.free(g);
            }
            dgates.clear();

            // ln2 backward + residual
            {
                let rep = self.rep.as_ref().map(|r| &r.layers[l]);
                let g = rep.map(|r| r.ln2_g.clone());
                let mut outs = ctx.call_op(
                    Op::LnBwd,
                    b,
                    n,
                    &[x_mid.buf.arg(), arg_of(g.as_ref()), dm.buf.arg()],
                    &[acts, MemCategory::Grads, MemCategory::Grads],
                )?;
                let db = outs.pop().unwrap();
                let dg = outs.pop().unwrap();
                let dxl = outs.pop().unwrap();
                if let Some(gr) = self.g_rep.as_mut() {
                    gr.layers[l].ln2_g.add_assign(dg.f());
                    gr.layers[l].ln2_b.add_assign(db.f());
                }
                ctx.free(db);
                ctx.free(dg);
                ctx.accumulate(&mut dx, &dxl);
                ctx.free(dxl);
            }
            ctx.free(dm);
            ctx.free(m);
            ctx.free(x_mid);

            // bo grads + attention backward rotation
            if let Some(gr) = self.g_rep.as_mut() {
                gr.layers[l].bo.add_assign(&dx.f().sum_leading());
            }
            let mut da = ctx.alloc(acts, Buf::zeros_like_mode(virt, &[b, cfg.seq, h]))?;
            {
                let mut gring: GradSlot<AttnShard> = GradSlot {
                    id: self.rings.attn[l].id,
                    data: self.rings.attn[l].data.as_ref().map(|s| zero_like_attn(s)),
                };
                for t in 0..n {
                    let pending = if t + 1 < n {
                        Some(Self::rotate_begin(
                            ctx,
                            variant,
                            &self.rings.attn[l],
                            self.bytes.attn,
                            false,
                        ))
                    } else {
                        None
                    };
                    let sid = self.rings.attn[l].id;
                    {
                        let sh = self.rings.attn[l].data.as_ref();
                        let mut outs = ctx.call_op(
                            Op::AttnBwd,
                            b,
                            n,
                            &[
                                a.buf.arg(),
                                arg_of(sh.map(|s| &s.wqkv)),
                                arg_of(sh.map(|s| &s.bqkv)),
                                arg_of(sh.map(|s| &s.wo)),
                                dx.buf.arg(),
                            ],
                            &[
                                acts,
                                MemCategory::Grads,
                                MemCategory::Grads,
                                MemCategory::Grads,
                            ],
                        )?;
                        let dwo = outs.pop().unwrap();
                        let dbq = outs.pop().unwrap();
                        let dwq = outs.pop().unwrap();
                        let d = outs.pop().unwrap();
                        if let Some(g) = gring.data.as_mut() {
                            g.wo.add_assign(dwo.f());
                            g.bqkv.add_assign(dbq.f());
                            g.wqkv.add_assign(dwq.f());
                        }
                        ctx.accumulate(&mut da, &d);
                        ctx.free(d);
                        ctx.free(dwo);
                        ctx.free(dbq);
                        ctx.free(dwq);
                    }
                    ctx.trace(TraceEvent::Compute {
                        worker: w,
                        unit: format!("attn.l{l}.bwd"),
                        shard: sid,
                        step: t,
                    });
                    if let Some(p) = pending {
                        Self::rotate_finish(
                            ctx,
                            variant,
                            &mut self.rings.attn[l],
                            Some(&mut gring),
                            p,
                            self.bytes.attn,
                            false,
                            t,
                        );
                    }
                }
                debug_assert_eq!(gring.id, w, "attn gring {l} not home");
                if let (Some(home), Some(g)) = (self.grads.attn.as_mut(), gring.data) {
                    home[l].wqkv.axpy(scale, &g.wqkv);
                    home[l].bqkv.axpy(scale, &g.bqkv);
                    home[l].wo.axpy(scale, &g.wo);
                }
            }

            // ln1 backward
            {
                let rep = self.rep.as_ref().map(|r| &r.layers[l]);
                let g = rep.map(|r| r.ln1_g.clone());
                let mut outs = ctx.call_op(
                    Op::LnBwd,
                    b,
                    n,
                    &[x_in.buf.arg(), arg_of(g.as_ref()), da.buf.arg()],
                    &[acts, MemCategory::Grads, MemCategory::Grads],
                )?;
                let db = outs.pop().unwrap();
                let dg = outs.pop().unwrap();
                let dxl = outs.pop().unwrap();
                if let Some(gr) = self.g_rep.as_mut() {
                    gr.layers[l].ln1_g.add_assign(dg.f());
                    gr.layers[l].ln1_b.add_assign(db.f());
                }
                ctx.free(db);
                ctx.free(dg);
                ctx.accumulate(&mut dx, &dxl);
                ctx.free(dxl);
            }
            ctx.free(da);
            ctx.free(a);
            ctx.free(x_in);
        }

        // embedding backward rotation (ring is at its post-forward
        // position, counter-rotates home)
        {
            let mut gring: GradSlot<EmbShard> = GradSlot {
                id: self.rings.emb.id,
                data: self.rings.emb.data.as_ref().map(|e| zero_like_emb(e)),
            };
            for t in 0..n {
                let pending = if t + 1 < n {
                    Some(Self::rotate_begin(ctx, variant, &self.rings.emb, self.bytes.emb, false))
                } else {
                    None
                };
                let sid = self.rings.emb.id;
                {
                    let dx_w = ctx.col_slice(&dx, sid * hp, hp, acts)?;
                    let mut outs = ctx.call_op(
                        Op::EmbBwd,
                        b,
                        n,
                        &[ids.buf.arg(), dx_w.buf.arg()],
                        &[MemCategory::Grads, MemCategory::Grads],
                    )?;
                    let dwpe = outs.pop().unwrap();
                    let dwte = outs.pop().unwrap();
                    if let Some(g) = gring.data.as_mut() {
                        g.wte.add_assign(dwte.f());
                        g.wpe.add_assign(dwpe.f());
                    }
                    ctx.free(dwte);
                    ctx.free(dwpe);
                    ctx.free(dx_w);
                }
                ctx.trace(TraceEvent::Compute {
                    worker: w,
                    unit: "emb.bwd".to_string(),
                    shard: sid,
                    step: t,
                });
                if let Some(p) = pending {
                    Self::rotate_finish(
                        ctx,
                        variant,
                        &mut self.rings.emb,
                        Some(&mut gring),
                        p,
                        self.bytes.emb,
                        false,
                        t,
                    );
                }
            }
            debug_assert_eq!(gring.id, w, "emb gring not home");
            if let (Some(home), Some(g)) = (self.grads.emb.as_mut(), gring.data) {
                home.wte.axpy(scale, &g.wte);
                home.wpe.axpy(scale, &g.wpe);
            }
        }
        ctx.free(dx);
        ctx.free(ids);

        // replicated grads: one small allreduce replaces nothing the paper
        // counts (LNs + biases + router), but we charge it honestly —
        // 2(N-1) ring hops through this rank's own port
        if n > 1 {
            let rep_bytes = (replicated_elems(&cfg) * 4) as u64;
            ctx.charge_comm("ar-replicated", CommPrim::AllReduce, rep_bytes);
            if let Some(gr) = self.g_rep.as_mut() {
                // allreduce-MEAN: idempotent on values that earlier steps
                // already reduced, so grads accumulate correctly across
                // steps without zeroing. The flattening scratch persists
                // on the rank, so this path allocates nothing per step;
                // the ring hops ride the background collective engine
                // (identical chunk schedule, bit-identical values).
                if self.coll.is_none() {
                    self.coll = Some(ctx.collectives());
                }
                let stream = self.coll.as_ref().unwrap();
                let mut flat = std::mem::take(&mut self.rep_scratch);
                gr.pack_into(&mut flat);
                match ctx.bucket_elems() {
                    // size-targeted buckets: all in flight at once for
                    // the hop scheduler to interleave
                    Some(target) => {
                        self.rep_buckets.allreduce_flat(stream, &mut flat, target);
                    }
                    None => flat = stream.join(stream.issue_allreduce(flat)),
                }
                gr.unpack(&flat);
                gr.visit_mut(&mut |t| t.scale(scale));
                self.rep_scratch = flat;
            }
        }
        if let Some(tl) = ctx.timeline.as_deref_mut() {
            tl.barrier();
        }

        // every ring must be home again — the paper's Fig-1 invariant
        debug_assert_eq!(self.rings.emb.id, w, "emb ring not home");
        for (l, r) in self.rings.attn.iter().enumerate() {
            debug_assert_eq!(r.id, w, "attn ring {l} not home");
        }
        for (l, r) in self.rings.mlp.iter().enumerate() {
            debug_assert_eq!(r.id, w, "mlp ring {l} not home");
        }
        debug_assert_eq!(self.rings.lm.id, w, "lm ring not home");

        Ok(loss)
    }

    fn gather_params_local(&self, port: &RingPort) -> ModelParams {
        let cfg = &self.cfg;
        let heads = cfg.heads;
        let hd = cfg.head_dim();
        let rep = self.rep.as_ref().expect("virtual mode");
        let emb = self.rings.emb.data.as_ref().expect("virtual mode");
        debug_assert_eq!(self.rings.emb.id, self.rank, "rings must be home to gather");
        let mut out = ModelParams::zeros_like(cfg);
        out.wte = partition::unshard_cols(&allgather_tensor(port, &emb.wte));
        out.wpe = partition::unshard_cols(&allgather_tensor(port, &emb.wpe));
        for (l, lp) in out.layers.iter_mut().enumerate() {
            let attn = self.rings.attn[l].data.as_ref().expect("virtual mode");
            lp.wqkv = partition::unshard_qkv_cols(
                &allgather_tensor(port, &attn.wqkv),
                heads,
                hd,
            );
            lp.bqkv = partition::unshard_qkv_cols(
                &allgather_tensor(port, &attn.bqkv),
                heads,
                hd,
            );
            lp.wo = partition::unshard_rows(&allgather_tensor(port, &attn.wo));
            let mlp = self.rings.mlp[l].data.as_ref().expect("virtual mode");
            let rl = &rep.layers[l];
            lp.mlp = assemble_mlp(port, mlp, rl, cfg);
            lp.ln1_g = rl.ln1_g.clone();
            lp.ln1_b = rl.ln1_b.clone();
            lp.bo = rl.bo.clone();
            lp.ln2_g = rl.ln2_g.clone();
            lp.ln2_b = rl.ln2_b.clone();
        }
        out.lnf_g = rep.lnf_g.clone();
        out.lnf_b = rep.lnf_b.clone();
        let lm = self.rings.lm.data.as_ref().expect("virtual mode");
        out.wlm = partition::unshard_cols(&allgather_tensor(port, lm));
        out
    }

    fn gather_grads_local(&self, port: &RingPort) -> ModelParams {
        let cfg = &self.cfg;
        let heads = cfg.heads;
        let hd = cfg.head_dim();
        let grep = self.g_rep.as_ref().expect("virtual mode");
        let emb = self.grads.emb.as_ref().expect("virtual mode");
        let mut out = ModelParams::zeros_like(cfg);
        out.wte = partition::unshard_cols(&allgather_tensor(port, &emb.wte));
        out.wpe = partition::unshard_cols(&allgather_tensor(port, &emb.wpe));
        let gattn = self.grads.attn.as_ref().expect("virtual mode");
        let gmlp = self.grads.mlp.as_ref().expect("virtual mode");
        for (l, lp) in out.layers.iter_mut().enumerate() {
            lp.wqkv = partition::unshard_qkv_cols(
                &allgather_tensor(port, &gattn[l].wqkv),
                heads,
                hd,
            );
            lp.bqkv = partition::unshard_qkv_cols(
                &allgather_tensor(port, &gattn[l].bqkv),
                heads,
                hd,
            );
            lp.wo = partition::unshard_rows(&allgather_tensor(port, &gattn[l].wo));
            let rl = &grep.layers[l];
            lp.mlp = assemble_mlp(port, &gmlp[l], rl, cfg);
            lp.ln1_g = rl.ln1_g.clone();
            lp.ln1_b = rl.ln1_b.clone();
            lp.bo = rl.bo.clone();
            lp.ln2_g = rl.ln2_g.clone();
            lp.ln2_b = rl.ln2_b.clone();
        }
        out.lnf_g = grep.lnf_g.clone();
        out.lnf_b = grep.lnf_b.clone();
        let lm = self.grads.lm.as_ref().expect("virtual mode");
        out.wlm = partition::unshard_cols(&allgather_tensor(port, lm));
        out
    }

    fn visit_owned(&mut self, f: &mut dyn FnMut(&mut HostTensor, &HostTensor)) {
        // weights are home after a full step: this slot holds shard
        // `rank`, and its Arc is unique again (no rotation in flight), so
        // the optimizer mutates the tensors in place
        let (Some(wd), Some(gd)) = (self.rings.emb.data.as_mut(), self.grads.emb.as_ref())
        else {
            return;
        };
        let wd = Arc::get_mut(wd)
            .unwrap_or_else(|| panic!("emb shard aliased: rotation still in flight"));
        f(&mut wd.wte, &gd.wte);
        f(&mut wd.wpe, &gd.wpe);
        for (ring, g) in self
            .rings
            .attn
            .iter_mut()
            .zip(self.grads.attn.as_ref().unwrap())
        {
            let p = Arc::get_mut(ring.data.as_mut().unwrap())
                .unwrap_or_else(|| panic!("attn shard aliased: rotation still in flight"));
            f(&mut p.wqkv, &g.wqkv);
            f(&mut p.bqkv, &g.bqkv);
            f(&mut p.wo, &g.wo);
        }
        for (ring, g) in self
            .rings
            .mlp
            .iter_mut()
            .zip(self.grads.mlp.as_ref().unwrap())
        {
            let p = Arc::get_mut(ring.data.as_mut().unwrap())
                .unwrap_or_else(|| panic!("mlp shard aliased: rotation still in flight"));
            match (p, g) {
                (MlpShardV::Dense(pd), MlpShardV::Dense(gd)) => {
                    f(&mut pd.w1, &gd.w1);
                    f(&mut pd.b1, &gd.b1);
                    f(&mut pd.w2, &gd.w2);
                }
                (MlpShardV::Experts(px), MlpShardV::Experts(gx)) => {
                    for (pe, ge) in px.iter_mut().zip(gx) {
                        f(&mut pe.w1, &ge.w1);
                        f(&mut pe.b1, &ge.b1);
                        f(&mut pe.w2, &ge.w2);
                    }
                }
                _ => unreachable!(),
            }
        }
        f(
            Arc::get_mut(self.rings.lm.data.as_mut().unwrap())
                .unwrap_or_else(|| panic!("lm shard aliased: rotation still in flight")),
            self.grads.lm.as_ref().unwrap(),
        );
        // replicated params: identical update on every rank's copy
        let grep = self.g_rep.as_ref().unwrap();
        let mut gs: Vec<*const HostTensor> = Vec::new();
        grep.visit(&mut |t| gs.push(t));
        let mut i = 0;
        self.rep.as_mut().unwrap().visit_mut(&mut |t| {
            // SAFETY: parallel traversal of structurally-equal trees
            f(t, unsafe { &*gs[i] });
            i += 1;
        });
    }

    fn zero_grads(&mut self) {
        if let Some(e) = self.grads.emb.as_mut() {
            e.wte.data.fill(0.0);
            e.wpe.data.fill(0.0);
        }
        if let Some(a) = self.grads.attn.as_mut() {
            for g in a {
                g.wqkv.data.fill(0.0);
                g.bqkv.data.fill(0.0);
                g.wo.data.fill(0.0);
            }
        }
        if let Some(ms) = self.grads.mlp.as_mut() {
            for g in ms {
                match g {
                    MlpShardV::Dense(d) => {
                        d.w1.data.fill(0.0);
                        d.b1.data.fill(0.0);
                        d.w2.data.fill(0.0);
                    }
                    MlpShardV::Experts(ex) => {
                        for e in ex {
                            e.w1.data.fill(0.0);
                            e.b1.data.fill(0.0);
                            e.w2.data.fill(0.0);
                        }
                    }
                }
            }
        }
        if let Some(lm) = self.grads.lm.as_mut() {
            lm.data.fill(0.0);
        }
        if let Some(gr) = self.g_rep.as_mut() {
            gr.visit_mut(&mut |t| t.data.fill(0.0));
        }
    }

    fn load_full(&mut self, full: &ModelParams) -> Result<()> {
        if self.rep.is_none() {
            anyhow::bail!("load_full: no shards in virtual mode");
        }
        let (rank, n) = (self.rank, self.n);
        let cfg = self.cfg.clone();
        // rings are home at every step boundary (the Fig-1 invariant,
        // asserted at the end of each step), so rotation offset is always
        // 0 here — resuming never has to undo a partial rotation
        debug_assert_eq!(self.rings.emb.id, rank, "emb ring must be home to load");
        debug_assert_eq!(self.rings.lm.id, rank, "lm ring must be home to load");
        let heads = cfg.heads;
        let hd = cfg.head_dim();
        // replay the constructor's partitioning: each rank keeps its home
        // shard of every unit (grad shapes are unchanged — same n)
        self.rings.emb = RingSlot::home(
            rank,
            Some(EmbShard {
                wte: partition::shard_cols(&full.wte, rank, n),
                wpe: partition::shard_cols(&full.wpe, rank, n),
            }),
        );
        self.rings.attn = full
            .layers
            .iter()
            .map(|lp| {
                RingSlot::home(
                    rank,
                    Some(partition::attn_shard(
                        &lp.wqkv, &lp.bqkv, &lp.wo, rank, n, heads, hd,
                    )),
                )
            })
            .collect();
        self.rings.mlp = full
            .layers
            .iter()
            .map(|lp| {
                RingSlot::home(
                    rank,
                    Some(match &lp.mlp {
                        MlpParams::Dense { w1, b1, w2, .. } => {
                            MlpShardV::Dense(partition::mlp_shard(w1, b1, w2, rank, n))
                        }
                        MlpParams::Moe { experts, .. } => MlpShardV::Experts(
                            partition::expert_range(rank, n, cfg.experts)
                                .map(|e| experts[e].clone())
                                .collect(),
                        ),
                    }),
                )
            })
            .collect();
        self.rings.lm =
            RingSlot::home(rank, Some(partition::shard_cols(&full.wlm, rank, n)));
        self.rep = Some(RepParams::from_full(full));
        Ok(())
    }
}

/// Reassemble one layer's MLP (dense shards or expert groups) from this
/// rank's shard by allgathering each tensor through `port`.
fn assemble_mlp(
    port: &RingPort,
    mine: &MlpShardV,
    rl: &super::common::RepLayer,
    cfg: &ModelCfg,
) -> MlpParams {
    match mine {
        MlpShardV::Dense(d) => MlpParams::Dense {
            w1: partition::unshard_cols(&allgather_tensor(port, &d.w1)),
            b1: partition::unshard_cols(&allgather_tensor(port, &d.b1)),
            w2: partition::unshard_rows(&allgather_tensor(port, &d.w2)),
            b2: rl.b2.clone(),
        },
        MlpShardV::Experts(mine_ex) => {
            let n = port.n();
            let per = cfg.experts / n;
            // experts[s*per + k] = rank s's k-th expert
            let mut experts: Vec<Option<ExpertParams>> =
                (0..cfg.experts).map(|_| None).collect();
            for (k, ex) in mine_ex.iter().enumerate() {
                let w1s = allgather_tensor(port, &ex.w1);
                let b1s = allgather_tensor(port, &ex.b1);
                let w2s = allgather_tensor(port, &ex.w2);
                for (s, ((w1, b1), w2)) in
                    w1s.into_iter().zip(b1s).zip(w2s).enumerate()
                {
                    experts[s * per + k] = Some(ExpertParams { w1, b1, w2 });
                }
            }
            MlpParams::Moe {
                wr: rl.wr.clone().expect("moe router"),
                experts: experts.into_iter().map(|e| e.expect("expert hole")).collect(),
                b2: rl.b2.clone(),
            }
        }
    }
}

// keep `shard_at` linked for the schedule tests even though the slots
// track positions directly
#[allow(dead_code)]
fn schedule_check(n: usize) -> bool {
    (0..n).all(|w| comm::shard_at(RotationDir::Clockwise, w, 0, n) == w)
}
