//! The op catalog — the single source of truth for what compute exists.
//!
//! Every entry mirrors one AOT'd HLO artifact emitted by
//! `python/compile/aot.py` (keys `{op}__b{b}__p{p}[__pallas]`). The shape
//! functions reproduce the python arg specs exactly; the cost functions
//! price each op for the perf model (gemm list for occupancy modeling +
//! elementwise byte traffic).

use crate::config::ModelCfg;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    EmbFwd,
    EmbBwd,
    LnFwd,
    LnBwd,
    AttnFwd,
    AttnBwd,
    MlpFwd,
    MlpBwd,
    LmheadFwd,
    LmheadBwd,
    Xent,
    RouterFwd,
    RouterBwd,
    MoeFwd,
    MoeBwd,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl Op {
    pub const ALL: [Op; 15] = [
        Op::EmbFwd,
        Op::EmbBwd,
        Op::LnFwd,
        Op::LnBwd,
        Op::AttnFwd,
        Op::AttnBwd,
        Op::MlpFwd,
        Op::MlpBwd,
        Op::LmheadFwd,
        Op::LmheadBwd,
        Op::Xent,
        Op::RouterFwd,
        Op::RouterBwd,
        Op::MoeFwd,
        Op::MoeBwd,
    ];

    pub fn key_name(&self) -> &'static str {
        match self {
            Op::EmbFwd => "emb_fwd",
            Op::EmbBwd => "emb_bwd",
            Op::LnFwd => "ln_fwd",
            Op::LnBwd => "ln_bwd",
            Op::AttnFwd => "attn_fwd",
            Op::AttnBwd => "attn_bwd",
            Op::MlpFwd => "mlp_fwd",
            Op::MlpBwd => "mlp_bwd",
            Op::LmheadFwd => "lmhead_fwd",
            Op::LmheadBwd => "lmhead_bwd",
            Op::Xent => "xent",
            Op::RouterFwd => "router_fwd",
            Op::RouterBwd => "router_bwd",
            Op::MoeFwd => "moe_fwd",
            Op::MoeBwd => "moe_bwd",
        }
    }

    /// Manifest key for a (local batch, partition) instance.
    pub fn artifact_key(&self, b: usize, p: usize, pallas: bool) -> String {
        // loss + MoE ops are emitted once per batch under p=1 (aot.py)
        let p = if self.batch_only() { 1 } else { p };
        let suffix = if pallas { "__pallas" } else { "" };
        format!("{}__b{}__p{}{}", self.key_name(), b, p, suffix)
    }

    /// Ops whose artifact shape depends only on the local batch, not on
    /// the partition factor (xent; MoE per-expert ops).
    pub fn batch_only(&self) -> bool {
        matches!(
            self,
            Op::Xent | Op::RouterFwd | Op::RouterBwd | Op::MoeFwd | Op::MoeBwd
        )
    }
}

impl std::fmt::Display for Op {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.key_name())
    }
}

/// Input dtypes+shapes for `op` at local batch `b`, partition factor `p` —
/// mirrors `aot.py::op_instances` arg specs.
pub fn input_shapes(op: Op, cfg: &ModelCfg, b: usize, p: usize) -> Vec<(DType, Vec<usize>)> {
    let (v, h, s, f) = (cfg.vocab, cfg.hidden, cfg.seq, cfg.ffn);
    let (hp, fp, vp) = (h / p, f / p, v / p);
    let (e, fe) = (cfg.experts, cfg.expert_ffn);
    use DType::*;
    match op {
        Op::EmbFwd => vec![(I32, vec![b, s]), (F32, vec![v, hp]), (F32, vec![s, hp])],
        Op::EmbBwd => vec![(I32, vec![b, s]), (F32, vec![b, s, hp])],
        Op::LnFwd => vec![(F32, vec![b, s, h]), (F32, vec![h]), (F32, vec![h])],
        // NOTE: ln_bwd takes (x, g, dy) — the bias value does not enter
        // any gradient (python/compile/model.py)
        Op::LnBwd => vec![
            (F32, vec![b, s, h]),
            (F32, vec![h]),
            (F32, vec![b, s, h]),
        ],
        Op::AttnFwd => vec![
            (F32, vec![b, s, h]),
            (F32, vec![h, 3 * hp]),
            (F32, vec![3 * hp]),
            (F32, vec![hp, h]),
        ],
        Op::AttnBwd => vec![
            (F32, vec![b, s, h]),
            (F32, vec![h, 3 * hp]),
            (F32, vec![3 * hp]),
            (F32, vec![hp, h]),
            (F32, vec![b, s, h]),
        ],
        Op::MlpFwd => vec![
            (F32, vec![b, s, h]),
            (F32, vec![h, fp]),
            (F32, vec![fp]),
            (F32, vec![fp, h]),
        ],
        Op::MlpBwd => vec![
            (F32, vec![b, s, h]),
            (F32, vec![h, fp]),
            (F32, vec![fp]),
            (F32, vec![fp, h]),
            (F32, vec![b, s, h]),
        ],
        Op::LmheadFwd => vec![(F32, vec![b, s, h]), (F32, vec![h, vp])],
        Op::LmheadBwd => {
            vec![(F32, vec![b, s, h]), (F32, vec![h, vp]), (F32, vec![b, s, vp])]
        }
        Op::Xent => vec![(F32, vec![b, s, v]), (I32, vec![b, s])],
        Op::RouterFwd => vec![(F32, vec![b, s, h]), (F32, vec![h, e])],
        Op::RouterBwd => {
            vec![(F32, vec![b, s, h]), (F32, vec![h, e]), (F32, vec![b, s, e])]
        }
        Op::MoeFwd => vec![
            (F32, vec![b, s, h]),
            (F32, vec![b, s]),
            (F32, vec![h, fe]),
            (F32, vec![fe]),
            (F32, vec![fe, h]),
        ],
        Op::MoeBwd => vec![
            (F32, vec![b, s, h]),
            (F32, vec![b, s]),
            (F32, vec![h, fe]),
            (F32, vec![fe]),
            (F32, vec![fe, h]),
            (F32, vec![b, s, h]),
        ],
    }
}

/// Output shapes (all f32) — mirrors the python op return tuples.
pub fn output_shapes(op: Op, cfg: &ModelCfg, b: usize, p: usize) -> Vec<Vec<usize>> {
    let (v, h, s, f) = (cfg.vocab, cfg.hidden, cfg.seq, cfg.ffn);
    let (hp, fp, vp) = (h / p, f / p, v / p);
    let (e, fe) = (cfg.experts, cfg.expert_ffn);
    match op {
        Op::EmbFwd => vec![vec![b, s, hp]],
        Op::EmbBwd => vec![vec![v, hp], vec![s, hp]],
        Op::LnFwd => vec![vec![b, s, h]],
        Op::LnBwd => vec![vec![b, s, h], vec![h], vec![h]],
        Op::AttnFwd => vec![vec![b, s, h]],
        Op::AttnBwd => {
            vec![vec![b, s, h], vec![h, 3 * hp], vec![3 * hp], vec![hp, h]]
        }
        Op::MlpFwd => vec![vec![b, s, h]],
        Op::MlpBwd => vec![vec![b, s, h], vec![h, fp], vec![fp], vec![fp, h]],
        Op::LmheadFwd => vec![vec![b, s, vp]],
        Op::LmheadBwd => vec![vec![b, s, h], vec![h, vp]],
        Op::Xent => vec![vec![], vec![b, s, v]],
        Op::RouterFwd => vec![vec![b, s, e]],
        Op::RouterBwd => vec![vec![b, s, h], vec![h, e]],
        Op::MoeFwd => vec![vec![b, s, h]],
        Op::MoeBwd => {
            vec![vec![b, s, h], vec![b, s], vec![h, fe], vec![fe], vec![fe, h]]
        }
    }
}

/// Cost profile of one op instance, for the roofline model (§3.4.1).
#[derive(Debug, Clone, Default)]
pub struct OpCost {
    /// GEMMs as (m, k, n) — the occupancy-relevant kernels.
    pub gemms: Vec<[usize; 3]>,
    /// Elementwise/reduction flops outside the GEMMs.
    pub ew_flops: f64,
    /// Total bytes touched (inputs + outputs, f32).
    pub bytes: f64,
}

impl OpCost {
    pub fn gemm_flops(&self) -> f64 {
        self.gemms.iter().map(|[m, k, n]| 2.0 * (*m as f64) * (*k as f64) * (*n as f64)).sum()
    }

    pub fn total_flops(&self) -> f64 {
        self.gemm_flops() + self.ew_flops
    }

    /// Number of kernel launches charged (one per GEMM + one fused
    /// elementwise kernel when any elementwise work exists).
    pub fn kernels(&self) -> usize {
        self.gemms.len() + usize::from(self.ew_flops > 0.0)
    }
}

fn io_bytes(op: Op, cfg: &ModelCfg, b: usize, p: usize) -> f64 {
    let ins: usize = input_shapes(op, cfg, b, p)
        .iter()
        .map(|(_, s)| s.iter().product::<usize>())
        .sum();
    let outs: usize = output_shapes(op, cfg, b, p)
        .iter()
        .map(|s| s.iter().product::<usize>().max(1))
        .sum();
    ((ins + outs) * 4) as f64
}

/// Cost of one op instance. Backward GEMMs are enumerated explicitly
/// (dx = dy·Wᵀ and dW = xᵀ·dy per forward GEMM — the standard 2× rule,
/// plus recomputation of the forward internals, matching the
/// recompute-from-inputs backward the artifacts implement).
pub fn op_cost(op: Op, cfg: &ModelCfg, b: usize, p: usize) -> OpCost {
    let (v, h, s, f) = (cfg.vocab, cfg.hidden, cfg.seq, cfg.ffn);
    let (hp, fp, vp) = (h / p, f / p, v / p);
    let (e, fe) = (cfg.experts, cfg.expert_ffn);
    let t = b * s; // token rows
    let hd = cfg.head_dim();
    let nh_p = cfg.heads / p;
    let bytes = io_bytes(op, cfg, b, p);
    let mut c = OpCost { bytes, ..Default::default() };
    match op {
        Op::EmbFwd => {
            // gather + add: elementwise only
            c.ew_flops = (t * hp) as f64;
        }
        Op::EmbBwd => {
            // scatter-add + reduction
            c.ew_flops = 2.0 * (t * hp) as f64;
        }
        Op::LnFwd => c.ew_flops = 8.0 * (t * h) as f64,
        Op::LnBwd => c.ew_flops = 16.0 * (t * h) as f64,
        Op::AttnFwd => {
            c.gemms.push([t, h, 3 * hp]); // qkv projection
            for _ in 0..b * nh_p {
                c.gemms.push([s, hd, s]); // q·kᵀ
                c.gemms.push([s, s, hd]); // p·v
            }
            c.gemms.push([t, hp, h]); // output projection
            c.ew_flops = 5.0 * (b * nh_p * s * s) as f64; // softmax+mask
        }
        Op::AttnBwd => {
            // recompute fwd + grads for each fwd GEMM
            let fwd = op_cost(Op::AttnFwd, cfg, b, p);
            c.gemms.extend_from_slice(&fwd.gemms);
            c.gemms.push([t, 3 * hp, h]); // dx  = dqkv·Wᵀ
            c.gemms.push([h, t, 3 * hp]); // dW  = xᵀ·dqkv
            for _ in 0..b * nh_p {
                c.gemms.push([s, hd, s]); // dlogits via do·vᵀ
                c.gemms.push([s, s, hd]); // dv
                c.gemms.push([s, s, hd]); // dq
                c.gemms.push([s, s, hd]); // dk
            }
            c.gemms.push([t, h, hp]); // do = dy·woᵀ
            c.gemms.push([hp, t, h]); // dwo
            c.ew_flops = 2.0 * fwd.ew_flops;
        }
        Op::MlpFwd => {
            c.gemms.push([t, h, fp]);
            c.gemms.push([t, fp, h]);
            c.ew_flops = 8.0 * (t * fp) as f64; // gelu
        }
        Op::MlpBwd => {
            c.gemms.push([t, h, fp]); // recompute hidden
            c.gemms.push([t, h, fp]); // dpre = dh*gelu' then dx path below
            c.gemms.push([t, fp, h]); // dh = dy·w2ᵀ
            c.gemms.push([fp, t, h]); // dw2
            c.gemms.push([t, fp, h]); // dx = dpre·w1ᵀ
            c.gemms.push([h, t, fp]); // dw1
            c.ew_flops = 16.0 * (t * fp) as f64;
        }
        Op::LmheadFwd => c.gemms.push([t, h, vp]),
        Op::LmheadBwd => {
            c.gemms.push([t, vp, h]); // dx
            c.gemms.push([h, t, vp]); // dW
        }
        Op::Xent => c.ew_flops = 6.0 * (t * v) as f64,
        Op::RouterFwd => {
            c.gemms.push([t, h, e]);
            c.ew_flops = 5.0 * (t * e) as f64;
        }
        Op::RouterBwd => {
            c.gemms.push([t, h, e]);
            c.gemms.push([t, e, h]);
            c.gemms.push([h, t, e]);
            c.ew_flops = 10.0 * (t * e) as f64;
        }
        Op::MoeFwd => {
            // top-1 routing sends ~t/E tokens to each expert; the engines'
            // dense-masked REAL compute runs all t rows (zero-gated), but
            // the perf model charges the routed-token cost every real MoE
            // system (incl. the paper's) pays. DESIGN.md §2 records this.
            let tr = (t / e.max(1)).max(1);
            c.gemms.push([tr, h, fe]);
            c.gemms.push([tr, fe, h]);
            c.ew_flops = 9.0 * (tr * fe) as f64;
        }
        Op::MoeBwd => {
            let tr = (t / e.max(1)).max(1);
            c.gemms.push([tr, h, fe]);
            c.gemms.push([tr, h, fe]);
            c.gemms.push([tr, fe, h]);
            c.gemms.push([fe, tr, h]);
            c.gemms.push([tr, fe, h]);
            c.gemms.push([h, tr, fe]);
            c.ew_flops = 18.0 * (tr * fe) as f64;
        }
    }
    c
}

/// Elements of every output of `op` — what the engines allocate.
pub fn output_elems(op: Op, cfg: &ModelCfg, b: usize, p: usize) -> usize {
    output_shapes(op, cfg, b, p)
        .iter()
        .map(|s| s.iter().product::<usize>().max(1))
        .sum()
}

// ---------------------------------------------------------------------------
// Incremental decode step (serving path)
//
// The serving engine ([`crate::serve`]) never re-runs a full-sequence
// forward: each generated token is ONE position pushed through the
// layers, attending over the cached K/V of every earlier position
// (kernels in `oracle::{qkv_decode_append, attn_decode_fwd, ...}`).
// These are not catalog `Op`s — their cost depends on the cache length,
// which the fixed `{op}__b{b}__p{p}` artifact keys cannot express — so
// the decode cost model lives here as a standalone closed form.
// ---------------------------------------------------------------------------

/// Cost of decoding ONE position for `b` active sequences on one of `p`
/// head-sharded ranks, with `cache_len` positions already cached
/// (the new position included — attention spans `cache_len` keys).
/// Sums all layers plus embedding, final LN and the rank's LM-head
/// shard; activation collectives (allreduce/allgather) are comm, not
/// compute, and are charged separately by the serve engine.
pub fn decode_step_cost(cfg: &ModelCfg, b: usize, p: usize, cache_len: usize) -> OpCost {
    let (v, h, f) = (cfg.vocab, cfg.hidden, cfg.ffn);
    let (hp, fp, vp) = (h / p, f / p, v / p);
    let l = cfg.layers;
    let mut c = OpCost::default();
    // embedding gather + add on this rank's hidden-column shard
    c.ew_flops += (b * hp) as f64;
    for _ in 0..l {
        // ln1 + ln2 (full hidden rows, replicated params)
        c.ew_flops += 2.0 * 8.0 * (b * h) as f64;
        // qkv projection for this rank's head group, one position
        c.gemms.push([b, h, 3 * hp]);
        // attention over the cache: per head, q·Kᵀ + softmax + probs·V
        c.ew_flops += (b * (2 * cache_len * hp + 5 * cache_len)) as f64;
        // output projection partial
        c.gemms.push([b, hp, h]);
        // mlp shard
        c.gemms.push([b, h, fp]);
        c.gemms.push([b, fp, h]);
        c.ew_flops += 9.0 * (b * fp) as f64;
    }
    // final ln + LM-head vocab shard
    c.ew_flops += 8.0 * (b * h) as f64;
    c.gemms.push([b, h, vp]);
    // bytes: weights shard touched once + KV cache read/append + small acts
    let weight_shard = cfg.weight_bytes() as f64 / p as f64;
    let kv_touched = (2 * l * cache_len * hp * 4) as f64 * b as f64;
    c.bytes = weight_shard + kv_touched + (b * (4 * h + 3 * hp + vp) * 4 * l) as f64;
    c
}

/// KV bytes APPENDED per decoded position per rank: K and V rows of the
/// rank's head shard, every layer (the steady-state growth rate the
/// admission controller projects forward).
pub fn decode_kv_bytes_per_token(cfg: &ModelCfg, p: usize) -> u64 {
    2 * cfg.layers as u64 * (cfg.hidden as u64 / p as u64) * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn tiny() -> ModelCfg {
        presets::get("tiny").unwrap()
    }

    #[test]
    fn artifact_keys_match_python_convention() {
        assert_eq!(Op::AttnFwd.artifact_key(2, 4, false), "attn_fwd__b2__p4");
        assert_eq!(Op::MlpBwd.artifact_key(1, 2, true), "mlp_bwd__b1__p2__pallas");
        // batch-only ops pin p=1 regardless of the engine's partition
        assert_eq!(Op::Xent.artifact_key(2, 4, false), "xent__b2__p1");
        assert_eq!(Op::MoeFwd.artifact_key(2, 4, false), "moe_fwd__b2__p1");
    }

    #[test]
    fn shard_shapes_divide_full_shapes() {
        let cfg = tiny();
        for op in Op::ALL {
            if op.batch_only() && cfg.experts == 0 && op != Op::Xent {
                continue;
            }
            let full = input_shapes(op, &cfg, 2, 1);
            let shard = input_shapes(op, &cfg, 2, 4);
            assert_eq!(full.len(), shard.len(), "{op}");
            for ((_, f), (_, s)) in full.iter().zip(&shard) {
                let fn_: usize = f.iter().product();
                let sn: usize = s.iter().product();
                assert!(fn_ % sn == 0, "{op}: {f:?} vs {s:?}");
            }
        }
    }

    #[test]
    fn backward_costs_exceed_forward() {
        let cfg = tiny();
        for (fwd, bwd) in [
            (Op::AttnFwd, Op::AttnBwd),
            (Op::MlpFwd, Op::MlpBwd),
            (Op::LmheadFwd, Op::LmheadBwd),
            (Op::LnFwd, Op::LnBwd),
        ] {
            let f = op_cost(fwd, &cfg, 2, 2).total_flops();
            let b = op_cost(bwd, &cfg, 2, 2).total_flops();
            assert!(b > 1.5 * f, "{bwd} flops {b} vs {fwd} {f}");
        }
    }

    #[test]
    fn shard_cost_is_about_one_over_p() {
        // The paper's E_compute = N × Kernel(B/N, I, O/N) claim: one shard
        // op does ~1/p of the full op's GEMM flops.
        let cfg = tiny();
        for op in [Op::AttnFwd, Op::MlpFwd, Op::LmheadFwd] {
            let full = op_cost(op, &cfg, 2, 1).gemm_flops();
            let shard = op_cost(op, &cfg, 2, 4).gemm_flops();
            let ratio = full / shard;
            assert!(
                (3.0..5.0).contains(&ratio),
                "{op}: full/shard = {ratio}"
            );
        }
    }

    #[test]
    fn output_elems_match_shapes() {
        let cfg = tiny();
        // xent outputs: scalar (counted as 1) + dlogits
        let n = output_elems(Op::Xent, &cfg, 2, 1);
        assert_eq!(n, 1 + 2 * cfg.seq * cfg.vocab);
    }

    #[test]
    fn gemm_flops_hand_value() {
        let c = OpCost { gemms: vec![[2, 3, 4]], ew_flops: 10.0, bytes: 0.0 };
        assert_eq!(c.gemm_flops(), 48.0);
        assert_eq!(c.total_flops(), 58.0);
        assert_eq!(c.kernels(), 2);
    }

    #[test]
    fn decode_step_is_far_cheaper_than_full_forward() {
        // the whole point of the serving path: one decoded token costs
        // ~1/seq of re-running the full-sequence forward
        let cfg = tiny();
        let full: f64 = [Op::EmbFwd, Op::LnFwd, Op::AttnFwd, Op::MlpFwd, Op::LmheadFwd]
            .iter()
            .map(|&op| op_cost(op, &cfg, 1, 1).total_flops())
            .sum();
        let decode = decode_step_cost(&cfg, 1, 1, cfg.seq).total_flops();
        assert!(
            decode * 2.0 < full,
            "decode step {decode} should be well under full forward {full}"
        );
    }

    #[test]
    fn decode_cost_scales_with_cache_len_and_shards() {
        let cfg = tiny();
        let short = decode_step_cost(&cfg, 2, 1, 4).total_flops();
        let long = decode_step_cost(&cfg, 2, 1, 16).total_flops();
        assert!(long > short);
        let full = decode_step_cost(&cfg, 2, 1, 8).gemm_flops();
        let shard = decode_step_cost(&cfg, 2, 4, 8).gemm_flops();
        let ratio = full / shard;
        assert!((2.0..5.0).contains(&ratio), "full/shard = {ratio}");
    }

    #[test]
    fn decode_kv_growth_rate_hand_value() {
        let cfg = tiny(); // 2 layers × 32 hidden
        // 2 (K+V) × 2 layers × 32 lanes × 4 B = 512 B/token unsharded
        assert_eq!(decode_kv_bytes_per_token(&cfg, 1), 512);
        assert_eq!(decode_kv_bytes_per_token(&cfg, 4), 128);
    }

    #[test]
    fn moe_shapes_use_expert_ffn() {
        let cfg = presets::get("tiny-moe").unwrap();
        let ins = input_shapes(Op::MoeFwd, &cfg, 2, 1);
        assert_eq!(ins[2].1, vec![cfg.hidden, cfg.expert_ffn]);
        let outs = output_shapes(Op::RouterFwd, &cfg, 2, 1);
        assert_eq!(outs[0], vec![2, cfg.seq, cfg.experts]);
    }
}
