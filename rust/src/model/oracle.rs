//! Pure-rust oracle for every catalog op.
//!
//! Numerically mirrors `python/compile/kernels/ref.py` + `model.py`
//! (tanh-GeLU, causal attention with recompute-from-inputs backward,
//! mean-reduced cross-entropy with dlogits pre-scaled by 1/T).
//!
//! Three jobs:
//! 1. unit/property tests of the engines run without AOT artifacts;
//! 2. an independent cross-check of the PJRT path (oracle == HLO within
//!    f32 tolerance, asserted in tests/integration_runtime.rs);
//! 3. finite-difference ground truth for every backward op (tests below).
//!
//! Not a performance path — the hot path dispatches to AOT'd HLO.

use crate::config::ModelCfg;
use crate::tensor::ops::gelu;
use crate::tensor::{HostTensor, IntTensor};

use super::ops::Op;

// ---------------------------------------------------------------------------
// flat 2-D matmul helpers (row-major)
// ---------------------------------------------------------------------------

/// c[m,n] = a[m,k] @ b[k,n]
fn mm(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut c = Vec::new();
    mm_into(a, m, k, b, n, &mut c);
    c
}

/// `mm` into a caller-owned buffer (cleared + zero-filled first) — the
/// serving decode path reuses scratch across steps so the hot loop does
/// no allocation once buffers reach capacity. Accumulation order is the
/// contract: kk ascending, zero `a` entries skipped, j ascending.
pub fn mm_into(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, c: &mut Vec<f32>) {
    c.clear();
    c.resize(m * n, 0.0);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// c[m,n] = a[m,k] @ b[n,k]ᵀ
fn mm_nt(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// c[m,n] = a[k,m]ᵀ @ b[k,n]
fn mm_tn(a: &[f32], k: usize, m: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

fn col_sum(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; cols];
    for r in 0..rows {
        for (o, v) in out.iter_mut().zip(&a[r * cols..(r + 1) * cols]) {
            *o += v;
        }
    }
    out
}

/// d/dx of tanh-approximate GeLU.
fn dgelu(x: f32) -> f32 {
    let c = (2.0 / std::f32::consts::PI).sqrt();
    let u = c * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * c * (1.0 + 3.0 * 0.044715 * x * x)
}

// ---------------------------------------------------------------------------
// embedding (Output-Partition)
// ---------------------------------------------------------------------------

/// ids [b,S] i32, wte [V,Hp], wpe [S,Hp] -> x [b,S,Hp]
pub fn emb_fwd(ids: &IntTensor, wte: &HostTensor, wpe: &HostTensor) -> HostTensor {
    let (b, s) = (ids.shape[0], ids.shape[1]);
    let hp = wte.last_dim();
    let mut x = HostTensor::zeros(&[b, s, hp]);
    for bi in 0..b {
        for si in 0..s {
            let id = ids.data[bi * s + si] as usize;
            let dst = &mut x.data[(bi * s + si) * hp..(bi * s + si + 1) * hp];
            let wte_row = &wte.data[id * hp..(id + 1) * hp];
            let wpe_row = &wpe.data[si * hp..(si + 1) * hp];
            for ((d, a), p) in dst.iter_mut().zip(wte_row).zip(wpe_row) {
                *d = a + p;
            }
        }
    }
    x
}

/// ids, dx [b,S,Hp] -> (dwte [V,Hp], dwpe [S,Hp])
pub fn emb_bwd(ids: &IntTensor, dx: &HostTensor, vocab: usize) -> (HostTensor, HostTensor) {
    let (b, s) = (ids.shape[0], ids.shape[1]);
    let hp = dx.last_dim();
    let mut dwte = HostTensor::zeros(&[vocab, hp]);
    let mut dwpe = HostTensor::zeros(&[s, hp]);
    for bi in 0..b {
        for si in 0..s {
            let id = ids.data[bi * s + si] as usize;
            let src = &dx.data[(bi * s + si) * hp..(bi * s + si + 1) * hp];
            for (o, v) in dwte.data[id * hp..(id + 1) * hp].iter_mut().zip(src) {
                *o += v;
            }
            for (o, v) in dwpe.data[si * hp..(si + 1) * hp].iter_mut().zip(src) {
                *o += v;
            }
        }
    }
    (dwte, dwpe)
}

// ---------------------------------------------------------------------------
// layernorm (replicated)
// ---------------------------------------------------------------------------

const LN_EPS: f32 = 1e-5;

/// x [...,H], g [H], b [H] -> y
pub fn ln_fwd(x: &HostTensor, g: &HostTensor, b: &HostTensor) -> HostTensor {
    let h = x.last_dim();
    let mut y = x.clone();
    for row in y.data.chunks_mut(h) {
        let mu = row.iter().sum::<f32>() / h as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / h as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (j, v) in row.iter_mut().enumerate() {
            *v = (*v - mu) * inv * g.data[j] + b.data[j];
        }
    }
    y
}

/// -> (dx, dg, db)
pub fn ln_bwd(
    x: &HostTensor,
    g: &HostTensor,
    dy: &HostTensor,
) -> (HostTensor, HostTensor, HostTensor) {
    let h = x.last_dim();
    let rows = x.rows();
    let mut dx = HostTensor::zeros(&x.shape);
    let mut dg = HostTensor::zeros(&[h]);
    let mut db = HostTensor::zeros(&[h]);
    for r in 0..rows {
        let xr = &x.data[r * h..(r + 1) * h];
        let dyr = &dy.data[r * h..(r + 1) * h];
        let mu = xr.iter().sum::<f32>() / h as f32;
        let var = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / h as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        let xhat: Vec<f32> = xr.iter().map(|v| (v - mu) * inv).collect();
        let dxhat: Vec<f32> = dyr.iter().zip(&g.data).map(|(d, gg)| d * gg).collect();
        let m1 = dxhat.iter().sum::<f32>() / h as f32;
        let m2 = dxhat.iter().zip(&xhat).map(|(a, b)| a * b).sum::<f32>() / h as f32;
        let dxr = &mut dx.data[r * h..(r + 1) * h];
        for j in 0..h {
            dxr[j] = (dxhat[j] - m1 - xhat[j] * m2) * inv;
            dg.data[j] += dyr[j] * xhat[j];
            db.data[j] += dyr[j];
        }
    }
    (dx, dg, db)
}

// ---------------------------------------------------------------------------
// attention (Number-of-head-Partition)
// ---------------------------------------------------------------------------

/// Causal softmax(q·kᵀ·scale)·v for one head: q, k, v [s, hd] ->
/// (probs [s,s], o [s,hd]).
fn head_attention(q: &[f32], k: &[f32], v: &[f32], s: usize, hd: usize)
    -> (Vec<f32>, Vec<f32>)
{
    let scale = 1.0 / (hd as f32).sqrt();
    let mut probs = vec![0.0f32; s * s];
    for i in 0..s {
        let qi = &q[i * hd..(i + 1) * hd];
        let mut max = f32::MIN;
        for j in 0..=i {
            let kj = &k[j * hd..(j + 1) * hd];
            let l: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
            probs[i * s + j] = l;
            max = max.max(l);
        }
        let mut sum = 0.0;
        for j in 0..=i {
            let e = (probs[i * s + j] - max).exp();
            probs[i * s + j] = e;
            sum += e;
        }
        for j in 0..=i {
            probs[i * s + j] /= sum;
        }
        // j > i stays exactly 0 (causal mask)
    }
    let o = mm(&probs, s, s, v, hd);
    (probs, o)
}

struct QkvView<'a> {
    qkv: &'a [f32],
    b: usize,
    s: usize,
    nh_p: usize,
    hd: usize,
}

impl<'a> QkvView<'a> {
    /// Extract q|k|v (`which` 0..3) for (batch bi, head) as a dense [s,hd].
    fn head(&self, which: usize, bi: usize, head: usize) -> Vec<f32> {
        let cols = 3 * self.nh_p * self.hd;
        let mut out = vec![0.0f32; self.s * self.hd];
        for si in 0..self.s {
            let row = (bi * self.s + si) * cols + which * self.nh_p * self.hd + head * self.hd;
            out[si * self.hd..(si + 1) * self.hd]
                .copy_from_slice(&self.qkv[row..row + self.hd]);
        }
        out
    }
}

/// Scatter a [s,hd] head block back into a [t, 3·nh_p·hd] qkv grad buffer.
fn scatter_head(
    dqkv: &mut [f32],
    block: &[f32],
    which: usize,
    bi: usize,
    head: usize,
    s: usize,
    nh_p: usize,
    hd: usize,
) {
    let cols = 3 * nh_p * hd;
    for si in 0..s {
        let row = (bi * s + si) * cols + which * nh_p * hd + head * hd;
        for d in 0..hd {
            dqkv[row + d] += block[si * hd + d];
        }
    }
}

/// x [b,S,H], wqkv [H,3Hp], bqkv [3Hp], wo [Hp,H] -> partial [b,S,H]
pub fn attn_fwd(
    x: &HostTensor,
    wqkv: &HostTensor,
    bqkv: &HostTensor,
    wo: &HostTensor,
    nh_p: usize,
) -> HostTensor {
    let (b, s, h) = (x.shape[0], x.shape[1], x.shape[2]);
    let hp3 = wqkv.last_dim();
    let hp = hp3 / 3;
    let hd = hp / nh_p;
    let t = b * s;
    let mut qkv = mm(&x.data, t, h, &wqkv.data, hp3);
    for row in qkv.chunks_mut(hp3) {
        for (v, bb) in row.iter_mut().zip(&bqkv.data) {
            *v += bb;
        }
    }
    let view = QkvView { qkv: &qkv, b, s, nh_p, hd };
    let mut o = vec![0.0f32; t * hp];
    for bi in 0..b {
        for head in 0..nh_p {
            let q = view.head(0, bi, head);
            let k = view.head(1, bi, head);
            let v = view.head(2, bi, head);
            let (_, oh) = head_attention(&q, &k, &v, s, hd);
            for si in 0..s {
                let dst = (bi * s + si) * hp + head * hd;
                o[dst..dst + hd].copy_from_slice(&oh[si * hd..(si + 1) * hd]);
            }
        }
    }
    let out = mm(&o, t, hp, &wo.data, h);
    HostTensor::from_vec(&[b, s, h], out)
}

/// Recompute-from-input backward. -> (dx, dwqkv, dbqkv, dwo)
pub fn attn_bwd(
    x: &HostTensor,
    wqkv: &HostTensor,
    bqkv: &HostTensor,
    wo: &HostTensor,
    dpartial: &HostTensor,
    nh_p: usize,
) -> (HostTensor, HostTensor, HostTensor, HostTensor) {
    let (b, s, h) = (x.shape[0], x.shape[1], x.shape[2]);
    let hp3 = wqkv.last_dim();
    let hp = hp3 / 3;
    let hd = hp / nh_p;
    let t = b * s;
    let scale = 1.0 / (hd as f32).sqrt();

    // recompute qkv and per-head attention
    let mut qkv = mm(&x.data, t, h, &wqkv.data, hp3);
    for row in qkv.chunks_mut(hp3) {
        for (v, bb) in row.iter_mut().zip(&bqkv.data) {
            *v += bb;
        }
    }
    let view = QkvView { qkv: &qkv, b, s, nh_p, hd };
    let mut o = vec![0.0f32; t * hp];
    let mut probs_all: Vec<Vec<f32>> = Vec::with_capacity(b * nh_p);
    for bi in 0..b {
        for head in 0..nh_p {
            let q = view.head(0, bi, head);
            let k = view.head(1, bi, head);
            let v = view.head(2, bi, head);
            let (probs, oh) = head_attention(&q, &k, &v, s, hd);
            for si in 0..s {
                let dst = (bi * s + si) * hp + head * hd;
                o[dst..dst + hd].copy_from_slice(&oh[si * hd..(si + 1) * hd]);
            }
            probs_all.push(probs);
        }
    }

    // output projection grads
    let dwo = mm_tn(&o, t, hp, &dpartial.data, h);
    let do_ = mm_nt(&dpartial.data, t, h, &wo.data, hp);

    // per-head attention backward -> dqkv
    let mut dqkv = vec![0.0f32; t * hp3];
    for bi in 0..b {
        for head in 0..nh_p {
            let probs = &probs_all[bi * nh_p + head];
            let q = view.head(0, bi, head);
            let k = view.head(1, bi, head);
            let v = view.head(2, bi, head);
            // slice this head's do [s,hd]
            let mut doh = vec![0.0f32; s * hd];
            for si in 0..s {
                let src = (bi * s + si) * hp + head * hd;
                doh[si * hd..(si + 1) * hd].copy_from_slice(&do_[src..src + hd]);
            }
            let dprobs = mm_nt(&doh, s, hd, &v, s); // [s,s]
            let dv = mm_tn(probs, s, s, &doh, hd); // [s,hd]
            // softmax backward (masked entries have probs == 0)
            let mut dl = vec![0.0f32; s * s];
            for i in 0..s {
                let pi = &probs[i * s..(i + 1) * s];
                let dpi = &dprobs[i * s..(i + 1) * s];
                let dot: f32 = pi.iter().zip(dpi).map(|(a, b)| a * b).sum();
                for j in 0..s {
                    dl[i * s + j] = pi[j] * (dpi[j] - dot);
                }
            }
            let mut dq = mm(&dl, s, s, &k, hd);
            dq.iter_mut().for_each(|v| *v *= scale);
            let mut dk = mm_tn(&dl, s, s, &q, hd);
            dk.iter_mut().for_each(|v| *v *= scale);
            scatter_head(&mut dqkv, &dq, 0, bi, head, s, nh_p, hd);
            scatter_head(&mut dqkv, &dk, 1, bi, head, s, nh_p, hd);
            scatter_head(&mut dqkv, &dv, 2, bi, head, s, nh_p, hd);
        }
    }

    let dbqkv = col_sum(&dqkv, t, hp3);
    let dwqkv = mm_tn(&x.data, t, h, &dqkv, hp3);
    let dx = mm_nt(&dqkv, t, hp3, &wqkv.data, h);
    (
        HostTensor::from_vec(&x.shape, dx),
        HostTensor::from_vec(&[h, hp3], dwqkv),
        HostTensor::from_vec(&[hp3], dbqkv),
        HostTensor::from_vec(&[hp, h], dwo),
    )
}

// ---------------------------------------------------------------------------
// MLP (Megatron pair)
// ---------------------------------------------------------------------------

/// x [b,S,H], w1 [H,Fp], b1 [Fp], w2 [Fp,H] -> partial [b,S,H]
pub fn mlp_fwd(x: &HostTensor, w1: &HostTensor, b1: &HostTensor, w2: &HostTensor)
    -> HostTensor
{
    let h = x.last_dim();
    let fp = w1.last_dim();
    let t = x.rows();
    let mut pre = mm(&x.data, t, h, &w1.data, fp);
    for row in pre.chunks_mut(fp) {
        for (v, bb) in row.iter_mut().zip(&b1.data) {
            *v = gelu(*v + bb);
        }
    }
    let y = mm(&pre, t, fp, &w2.data, h);
    HostTensor::from_vec(&x.shape, y)
}

/// -> (dx, dw1, db1, dw2)
pub fn mlp_bwd(
    x: &HostTensor,
    w1: &HostTensor,
    b1: &HostTensor,
    w2: &HostTensor,
    dy: &HostTensor,
) -> (HostTensor, HostTensor, HostTensor, HostTensor) {
    let h = x.last_dim();
    let fp = w1.last_dim();
    let t = x.rows();
    // recompute pre-activation and hidden
    let mut pre = mm(&x.data, t, h, &w1.data, fp);
    for row in pre.chunks_mut(fp) {
        for (v, bb) in row.iter_mut().zip(&b1.data) {
            *v += bb;
        }
    }
    let hid: Vec<f32> = pre.iter().map(|&v| gelu(v)).collect();
    let dh = mm_nt(&dy.data, t, h, &w2.data, fp);
    let dw2 = mm_tn(&hid, t, fp, &dy.data, h);
    let dpre: Vec<f32> = dh.iter().zip(&pre).map(|(d, &p)| d * dgelu(p)).collect();
    let db1 = col_sum(&dpre, t, fp);
    let dw1 = mm_tn(&x.data, t, h, &dpre, fp);
    let dx = mm_nt(&dpre, t, fp, &w1.data, h);
    (
        HostTensor::from_vec(&x.shape, dx),
        HostTensor::from_vec(&[h, fp], dw1),
        HostTensor::from_vec(&[fp], db1),
        HostTensor::from_vec(&[fp, h], dw2),
    )
}

// ---------------------------------------------------------------------------
// LM head (Output-Partition, no bias)
// ---------------------------------------------------------------------------

pub fn lmhead_fwd(x: &HostTensor, wlm: &HostTensor) -> HostTensor {
    let h = x.last_dim();
    let vp = wlm.last_dim();
    let t = x.rows();
    let y = mm(&x.data, t, h, &wlm.data, vp);
    let mut shape = x.shape.clone();
    *shape.last_mut().unwrap() = vp;
    HostTensor::from_vec(&shape, y)
}

/// -> (dx, dwlm)
pub fn lmhead_bwd(x: &HostTensor, wlm: &HostTensor, dl: &HostTensor)
    -> (HostTensor, HostTensor)
{
    let h = x.last_dim();
    let vp = wlm.last_dim();
    let t = x.rows();
    let dx = mm_nt(&dl.data, t, vp, &wlm.data, h);
    let dw = mm_tn(&x.data, t, h, &dl.data, vp);
    (
        HostTensor::from_vec(&x.shape, dx),
        HostTensor::from_vec(&[h, vp], dw),
    )
}

// ---------------------------------------------------------------------------
// loss
// ---------------------------------------------------------------------------

/// logits [b,S,V], targets [b,S] -> (mean loss, dlogits scaled by 1/T)
pub fn xent(logits: &HostTensor, targets: &IntTensor) -> (f32, HostTensor) {
    let v = logits.last_dim();
    let t = logits.rows();
    let mut dl = HostTensor::zeros(&logits.shape);
    let mut loss = 0.0f64;
    for r in 0..t {
        let row = &logits.data[r * v..(r + 1) * v];
        let max = row.iter().cloned().fold(f32::MIN, f32::max);
        let sum: f32 = row.iter().map(|x| (x - max).exp()).sum();
        let lse = max + sum.ln();
        let tgt = targets.data[r] as usize;
        loss += (lse - row[tgt]) as f64;
        let drow = &mut dl.data[r * v..(r + 1) * v];
        for j in 0..v {
            let p = (row[j] - lse).exp();
            drow[j] = p / t as f32;
        }
        drow[tgt] -= 1.0 / t as f32;
    }
    ((loss / t as f64) as f32, dl)
}

// ---------------------------------------------------------------------------
// MoE (Expert-Partition)
// ---------------------------------------------------------------------------

/// x [b,S,H], wr [H,E] -> probs [b,S,E]
pub fn router_fwd(x: &HostTensor, wr: &HostTensor) -> HostTensor {
    let h = x.last_dim();
    let e = wr.last_dim();
    let t = x.rows();
    let mut logits = mm(&x.data, t, h, &wr.data, e);
    for row in logits.chunks_mut(e) {
        let max = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    let mut shape = x.shape.clone();
    *shape.last_mut().unwrap() = e;
    HostTensor::from_vec(&shape, logits)
}

/// -> (dx, dwr)
pub fn router_bwd(x: &HostTensor, wr: &HostTensor, dprobs: &HostTensor)
    -> (HostTensor, HostTensor)
{
    let h = x.last_dim();
    let e = wr.last_dim();
    let t = x.rows();
    let probs = router_fwd(x, wr);
    let mut dlogits = vec![0.0f32; t * e];
    for r in 0..t {
        let pr = &probs.data[r * e..(r + 1) * e];
        let dpr = &dprobs.data[r * e..(r + 1) * e];
        let dot: f32 = pr.iter().zip(dpr).map(|(a, b)| a * b).sum();
        for j in 0..e {
            dlogits[r * e + j] = pr[j] * (dpr[j] - dot);
        }
    }
    let dx = mm_nt(&dlogits, t, e, &wr.data, h);
    let dwr = mm_tn(&x.data, t, h, &dlogits, e);
    (
        HostTensor::from_vec(&x.shape, dx),
        HostTensor::from_vec(&[h, e], dwr),
    )
}

/// Dense-masked single-expert FFN: y = (gelu(x·w1+b1)·w2) ⊙ gates.
pub fn moe_fwd(
    x: &HostTensor,
    gates: &HostTensor,
    w1: &HostTensor,
    b1: &HostTensor,
    w2: &HostTensor,
) -> HostTensor {
    let mut y = mlp_fwd(x, w1, b1, w2);
    let h = y.last_dim();
    for (r, g) in gates.data.iter().enumerate() {
        for v in &mut y.data[r * h..(r + 1) * h] {
            *v *= g;
        }
    }
    y
}

/// -> (dx, dgates, dw1, db1, dw2)
pub fn moe_bwd(
    x: &HostTensor,
    gates: &HostTensor,
    w1: &HostTensor,
    b1: &HostTensor,
    w2: &HostTensor,
    dpartial: &HostTensor,
) -> (HostTensor, HostTensor, HostTensor, HostTensor, HostTensor) {
    let h = x.last_dim();
    let yraw = mlp_fwd(x, w1, b1, w2);
    // dgates[r] = <dpartial[r], yraw[r]>
    let mut dgates = HostTensor::zeros(&gates.shape);
    for r in 0..x.rows() {
        dgates.data[r] = dpartial.data[r * h..(r + 1) * h]
            .iter()
            .zip(&yraw.data[r * h..(r + 1) * h])
            .map(|(a, b)| a * b)
            .sum();
    }
    // dyraw = dpartial ⊙ gates
    let mut dyraw = dpartial.clone();
    for (r, g) in gates.data.iter().enumerate() {
        for v in &mut dyraw.data[r * h..(r + 1) * h] {
            *v *= g;
        }
    }
    let (dx, dw1, db1, dw2) = mlp_bwd(x, w1, b1, w2, &dyraw);
    (dx, dgates, dw1, db1, dw2)
}

// ---------------------------------------------------------------------------
// dispatch (mirrors the artifact call convention)
// ---------------------------------------------------------------------------

/// A borrowed op argument — f32 tensor or i32 tensor.
#[derive(Debug, Clone, Copy)]
pub enum Arg<'a> {
    F(&'a HostTensor),
    I(&'a IntTensor),
}

impl<'a> Arg<'a> {
    pub fn f(&self) -> &'a HostTensor {
        match self {
            Arg::F(t) => t,
            Arg::I(_) => panic!("expected f32 arg"),
        }
    }
    pub fn i(&self) -> &'a IntTensor {
        match self {
            Arg::I(t) => t,
            Arg::F(_) => panic!("expected i32 arg"),
        }
    }
}

/// Run `op` with args in artifact order; returns outputs in artifact order.
/// The scalar loss of `xent` comes back as a shape-[] tensor.
pub fn run(op: Op, cfg: &ModelCfg, p: usize, args: &[Arg]) -> Vec<HostTensor> {
    let nh_p = cfg.heads / p;
    match op {
        Op::EmbFwd => vec![emb_fwd(args[0].i(), args[1].f(), args[2].f())],
        Op::EmbBwd => {
            let (dwte, dwpe) = emb_bwd(args[0].i(), args[1].f(), cfg.vocab);
            vec![dwte, dwpe]
        }
        Op::LnFwd => vec![ln_fwd(args[0].f(), args[1].f(), args[2].f())],
        Op::LnBwd => {
            let (dx, dg, db) = ln_bwd(args[0].f(), args[1].f(), args[2].f());
            vec![dx, dg, db]
        }
        Op::AttnFwd => {
            vec![attn_fwd(args[0].f(), args[1].f(), args[2].f(), args[3].f(), nh_p)]
        }
        Op::AttnBwd => {
            let (dx, dwqkv, dbqkv, dwo) = attn_bwd(
                args[0].f(),
                args[1].f(),
                args[2].f(),
                args[3].f(),
                args[4].f(),
                nh_p,
            );
            vec![dx, dwqkv, dbqkv, dwo]
        }
        Op::MlpFwd => vec![mlp_fwd(args[0].f(), args[1].f(), args[2].f(), args[3].f())],
        Op::MlpBwd => {
            let (dx, dw1, db1, dw2) =
                mlp_bwd(args[0].f(), args[1].f(), args[2].f(), args[3].f(), args[4].f());
            vec![dx, dw1, db1, dw2]
        }
        Op::LmheadFwd => vec![lmhead_fwd(args[0].f(), args[1].f())],
        Op::LmheadBwd => {
            let (dx, dw) = lmhead_bwd(args[0].f(), args[1].f(), args[2].f());
            vec![dx, dw]
        }
        Op::Xent => {
            let (loss, dl) = xent(args[0].f(), args[1].i());
            vec![HostTensor::scalar(loss), dl]
        }
        Op::RouterFwd => vec![router_fwd(args[0].f(), args[1].f())],
        Op::RouterBwd => {
            let (dx, dwr) = router_bwd(args[0].f(), args[1].f(), args[2].f());
            vec![dx, dwr]
        }
        Op::MoeFwd => vec![moe_fwd(
            args[0].f(),
            args[1].f(),
            args[2].f(),
            args[3].f(),
            args[4].f(),
        )],
        Op::MoeBwd => {
            let (dx, dg, dw1, db1, dw2) = moe_bwd(
                args[0].f(),
                args[1].f(),
                args[2].f(),
                args[3].f(),
                args[4].f(),
                args[5].f(),
            );
            vec![dx, dg, dw1, db1, dw2]
        }
    }
}

// ---------------------------------------------------------------------------
// incremental decode-step kernels (serving hot path; see crate::serve)
// ---------------------------------------------------------------------------
//
// Bitwise-parity contract: every helper below replays the EXACT float
// accumulation order of the full-sequence kernels above — `mm`'s
// kk-ascending skip-zero loop, `head_attention`'s j-ascending running
// max / exp / normalize, `ln_fwd`'s per-row mu/var/inv, the fused
// `gelu(v + b)` of `mlp_fwd`. A token decoded incrementally from the
// KV-cache is therefore bit-identical to the same position of a full
// forward (asserted in the tests below and in tests/serving.rs).
// All helpers write into caller-owned scratch: zero allocation at
// steady state on the decode hot path.

/// One embedding row per plan entry, `emb_fwd`'s `*d = a + p`, over a
/// hidden-column shard of `wte`/`wpe` (full tables when unsharded).
pub fn emb_decode_rows(
    ids: &[i32],
    positions: &[usize],
    wte_s: &HostTensor,
    wpe_s: &HostTensor,
    out: &mut Vec<f32>,
) {
    let lanes = wte_s.last_dim();
    out.clear();
    out.resize(ids.len() * lanes, 0.0);
    for (e, (&id, &pos)) in ids.iter().zip(positions).enumerate() {
        let dst = &mut out[e * lanes..(e + 1) * lanes];
        let wte_row = &wte_s.data[id as usize * lanes..(id as usize + 1) * lanes];
        let wpe_row = &wpe_s.data[pos * lanes..(pos + 1) * lanes];
        for ((d, a), p) in dst.iter_mut().zip(wte_row).zip(wpe_row) {
            *d = a + p;
        }
    }
}

/// Row-wise layernorm into caller scratch — `ln_fwd`'s exact order.
pub fn ln_rows_into(x: &[f32], g: &HostTensor, b: &HostTensor, out: &mut Vec<f32>) {
    let h = g.data.len();
    out.clear();
    out.extend_from_slice(x);
    for row in out.chunks_mut(h) {
        let mu = row.iter().sum::<f32>() / h as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / h as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (j, v) in row.iter_mut().enumerate() {
            *v = (*v - mu) * inv * g.data[j] + b.data[j];
        }
    }
}

/// `attn_fwd`'s post-matmul bias add: row-wise `*v += bb`.
pub fn add_bias_rows(buf: &mut [f32], bias: &[f32]) {
    for row in buf.chunks_mut(bias.len()) {
        for (v, bb) in row.iter_mut().zip(bias) {
            *v += bb;
        }
    }
}

/// `mlp_fwd`'s fused bias + activation: row-wise `*v = gelu(*v + bb)`.
pub fn bias_gelu_rows(buf: &mut [f32], bias: &[f32]) {
    for row in buf.chunks_mut(bias.len()) {
        for (v, bb) in row.iter_mut().zip(bias) {
            *v = gelu(*v + *bb);
        }
    }
}

/// Causal scores of ONE new query row against `rows` cached K rows
/// laid out `stride` lanes apart with this head at `head_off`:
/// scores[j] = (q·k_j)·scale, j ascending — `head_attention`'s inner
/// loop. Returns the running max folded from `seed` (pass `f32::MIN`
/// for the first page, the previous return for later pages: max is an
/// associative fold, so paging preserves the single-pass result).
pub fn attn_decode_scores(
    q_head: &[f32],
    k_rows: &[f32],
    rows: usize,
    stride: usize,
    head_off: usize,
    scale: f32,
    seed: f32,
    scores: &mut [f32],
) -> f32 {
    let hd = q_head.len();
    let mut max = seed;
    for (j, sc) in scores.iter_mut().enumerate().take(rows) {
        let kj = &k_rows[j * stride + head_off..j * stride + head_off + hd];
        let l: f32 = q_head.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
        *sc = l;
        max = max.max(l);
    }
    max
}

/// `head_attention`'s exp / sum / normalize over one score row, given
/// the running max: e_j ascending, summed ascending, then divided.
pub fn softmax_decode(scores: &mut [f32], max: f32) {
    let mut sum = 0.0f32;
    for v in scores.iter_mut() {
        let e = (*v - max).exp();
        *v = e;
        sum += e;
    }
    for v in scores.iter_mut() {
        *v /= sum;
    }
}

/// The `o = mm(probs, v)` row of `head_attention`: j ascending,
/// exact-zero probabilities skipped (as `mm` skips them), accumulated
/// into `out_head` (caller zeroes it before the first page).
pub fn attn_decode_weighted_sum(
    probs: &[f32],
    v_rows: &[f32],
    stride: usize,
    head_off: usize,
    out_head: &mut [f32],
) {
    let hd = out_head.len();
    for (j, &p) in probs.iter().enumerate() {
        if p == 0.0 {
            continue;
        }
        let vj = &v_rows[j * stride + head_off..j * stride + head_off + hd];
        for (o, vv) in out_head.iter_mut().zip(vj) {
            *o += p * vv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const FD_EPS: f32 = 1e-3;
    const FD_TOL: f32 = 2e-2;

    /// Central finite difference of `f` w.r.t. `x[idx]`.
    fn fd(f: &dyn Fn(&HostTensor) -> f32, x: &HostTensor, idx: usize) -> f32 {
        let mut xp = x.clone();
        xp.data[idx] += FD_EPS;
        let mut xm = x.clone();
        xm.data[idx] -= FD_EPS;
        (f(&xp) - f(&xm)) / (2.0 * FD_EPS)
    }

    /// Compare an analytic grad tensor against finite differences on a
    /// handful of indices (scalar objective = <out, probe>).
    fn check_grad(
        name: &str,
        f: &dyn Fn(&HostTensor) -> f32,
        x: &HostTensor,
        analytic: &HostTensor,
    ) {
        let idxs: Vec<usize> = (0..x.numel()).step_by((x.numel() / 7).max(1)).collect();
        for idx in idxs {
            let num = fd(f, x, idx);
            let ana = analytic.data[idx];
            // floor the denominator at 0.05: central differences in f32
            // carry ~1e-4 absolute noise, which would dominate near-zero
            // gradient entries.
            let denom = num.abs().max(ana.abs()).max(0.05);
            assert!(
                (num - ana).abs() / denom < FD_TOL,
                "{name}[{idx}]: fd {num} vs analytic {ana}"
            );
        }
    }

    fn probe(shape: &[usize], rng: &mut Rng) -> HostTensor {
        HostTensor::randn(shape, 1.0, rng)
    }

    fn dot(a: &HostTensor, b: &HostTensor) -> f32 {
        a.data.iter().zip(&b.data).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn ln_bwd_matches_fd() {
        let mut rng = Rng::new(11);
        let x = HostTensor::randn(&[2, 3, 8], 1.0, &mut rng);
        let g = HostTensor::randn(&[8], 0.5, &mut rng);
        let b = HostTensor::randn(&[8], 0.5, &mut rng);
        let pr = probe(&[2, 3, 8], &mut rng);
        let (dx, dg, db) = ln_bwd(&x, &g, &pr);
        check_grad("ln dx", &|xx| dot(&ln_fwd(xx, &g, &b), &pr), &x, &dx);
        check_grad("ln dg", &|gg| dot(&ln_fwd(&x, gg, &b), &pr), &g, &dg);
        check_grad("ln db", &|bb| dot(&ln_fwd(&x, &g, bb), &pr), &b, &db);
    }

    #[test]
    fn mlp_bwd_matches_fd() {
        let mut rng = Rng::new(12);
        let x = HostTensor::randn(&[1, 4, 6], 0.8, &mut rng);
        let w1 = HostTensor::randn(&[6, 10], 0.4, &mut rng);
        let b1 = HostTensor::randn(&[10], 0.2, &mut rng);
        let w2 = HostTensor::randn(&[10, 6], 0.4, &mut rng);
        let pr = probe(&[1, 4, 6], &mut rng);
        let (dx, dw1, db1, dw2) = mlp_bwd(&x, &w1, &b1, &w2, &pr);
        check_grad("mlp dx", &|t| dot(&mlp_fwd(t, &w1, &b1, &w2), &pr), &x, &dx);
        check_grad("mlp dw1", &|t| dot(&mlp_fwd(&x, t, &b1, &w2), &pr), &w1, &dw1);
        check_grad("mlp db1", &|t| dot(&mlp_fwd(&x, &w1, t, &w2), &pr), &b1, &db1);
        check_grad("mlp dw2", &|t| dot(&mlp_fwd(&x, &w1, &b1, t), &pr), &w2, &dw2);
    }

    #[test]
    fn attn_bwd_matches_fd() {
        let mut rng = Rng::new(13);
        let (b, s, h, nh) = (1, 4, 6, 2);
        let x = HostTensor::randn(&[b, s, h], 0.8, &mut rng);
        let wqkv = HostTensor::randn(&[h, 3 * h], 0.4, &mut rng);
        let bqkv = HostTensor::randn(&[3 * h], 0.2, &mut rng);
        let wo = HostTensor::randn(&[h, h], 0.4, &mut rng);
        let pr = probe(&[b, s, h], &mut rng);
        let (dx, dwqkv, dbqkv, dwo) = attn_bwd(&x, &wqkv, &bqkv, &wo, &pr, nh);
        check_grad("attn dx", &|t| dot(&attn_fwd(t, &wqkv, &bqkv, &wo, nh), &pr), &x, &dx);
        check_grad(
            "attn dwqkv",
            &|t| dot(&attn_fwd(&x, t, &bqkv, &wo, nh), &pr),
            &wqkv,
            &dwqkv,
        );
        check_grad(
            "attn dbqkv",
            &|t| dot(&attn_fwd(&x, &wqkv, t, &wo, nh), &pr),
            &bqkv,
            &dbqkv,
        );
        check_grad("attn dwo", &|t| dot(&attn_fwd(&x, &wqkv, &bqkv, t, nh), &pr), &wo, &dwo);
    }

    #[test]
    fn lmhead_bwd_matches_fd() {
        let mut rng = Rng::new(14);
        let x = HostTensor::randn(&[1, 3, 5], 0.8, &mut rng);
        let w = HostTensor::randn(&[5, 7], 0.4, &mut rng);
        let pr = probe(&[1, 3, 7], &mut rng);
        let (dx, dw) = lmhead_bwd(&x, &w, &pr);
        check_grad("lm dx", &|t| dot(&lmhead_fwd(t, &w), &pr), &x, &dx);
        check_grad("lm dw", &|t| dot(&lmhead_fwd(&x, t), &pr), &w, &dw);
    }

    #[test]
    fn xent_grad_matches_fd() {
        let mut rng = Rng::new(15);
        let logits = HostTensor::randn(&[2, 3, 6], 1.0, &mut rng);
        let targets = IntTensor::rand_below(&[2, 3], 6, &mut rng);
        let (_, dl) = xent(&logits, &targets);
        check_grad("xent dlogits", &|t| xent(t, &targets).0, &logits, &dl);
    }

    #[test]
    fn xent_perfect_prediction_low_loss() {
        // logits hugely favoring the target -> loss ~ 0
        let mut logits = HostTensor::zeros(&[1, 2, 4]);
        let targets = IntTensor::from_vec(&[1, 2], vec![2, 0]);
        logits.data[2] = 50.0;
        logits.data[4] = 50.0;
        let (loss, _) = xent(&logits, &targets);
        assert!(loss < 1e-4, "loss {loss}");
    }

    #[test]
    fn router_bwd_matches_fd() {
        let mut rng = Rng::new(16);
        let x = HostTensor::randn(&[1, 3, 5], 0.8, &mut rng);
        let wr = HostTensor::randn(&[5, 4], 0.4, &mut rng);
        let pr = probe(&[1, 3, 4], &mut rng);
        let (dx, dwr) = router_bwd(&x, &wr, &pr);
        check_grad("router dx", &|t| dot(&router_fwd(t, &wr), &pr), &x, &dx);
        check_grad("router dwr", &|t| dot(&router_fwd(&x, t), &pr), &wr, &dwr);
    }

    #[test]
    fn moe_bwd_matches_fd() {
        let mut rng = Rng::new(17);
        let x = HostTensor::randn(&[1, 3, 5], 0.8, &mut rng);
        let gates = HostTensor::randn(&[1, 3], 0.5, &mut rng);
        let w1 = HostTensor::randn(&[5, 8], 0.4, &mut rng);
        let b1 = HostTensor::randn(&[8], 0.2, &mut rng);
        let w2 = HostTensor::randn(&[8, 5], 0.4, &mut rng);
        let pr = probe(&[1, 3, 5], &mut rng);
        let (dx, dg, dw1, db1, dw2) = moe_bwd(&x, &gates, &w1, &b1, &w2, &pr);
        check_grad("moe dx", &|t| dot(&moe_fwd(t, &gates, &w1, &b1, &w2), &pr), &x, &dx);
        check_grad("moe dg", &|t| dot(&moe_fwd(&x, t, &w1, &b1, &w2), &pr), &gates, &dg);
        check_grad("moe dw1", &|t| dot(&moe_fwd(&x, &gates, t, &b1, &w2), &pr), &w1, &dw1);
        check_grad("moe db1", &|t| dot(&moe_fwd(&x, &gates, &w1, t, &w2), &pr), &b1, &db1);
        check_grad("moe dw2", &|t| dot(&moe_fwd(&x, &gates, &w1, &b1, t), &pr), &w2, &dw2);
    }

    #[test]
    fn emb_bwd_is_scatter_add() {
        let ids = IntTensor::from_vec(&[1, 3], vec![2, 0, 2]);
        let dx = HostTensor::from_vec(
            &[1, 3, 2],
            vec![1., 2., 10., 20., 100., 200.],
        );
        let (dwte, dwpe) = emb_bwd(&ids, &dx, 4);
        // token 2 appears twice: rows 0 and 2 of dx
        assert_eq!(&dwte.data[4..6], &[101., 202.]);
        assert_eq!(&dwte.data[0..2], &[10., 20.]);
        assert_eq!(&dwte.data[2..4], &[0., 0.]);
        // dwpe sums over batch (batch = 1 here: identity)
        assert_eq!(dwpe.data, dx.data);
    }

    #[test]
    fn emb_fwd_gathers_and_adds_positions() {
        let ids = IntTensor::from_vec(&[1, 2], vec![1, 0]);
        let wte = HostTensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let wpe = HostTensor::from_vec(&[2, 2], vec![10., 20., 30., 40.]);
        let x = emb_fwd(&ids, &wte, &wpe);
        assert_eq!(x.data, vec![13., 24., 31., 42.]);
    }

    #[test]
    fn causal_mask_blocks_future() {
        // Attention output at position 0 must not depend on position 1.
        let mut rng = Rng::new(18);
        let (b, s, h, nh) = (1, 3, 4, 2);
        let x = HostTensor::randn(&[b, s, h], 0.8, &mut rng);
        let wqkv = HostTensor::randn(&[h, 3 * h], 0.4, &mut rng);
        let bqkv = HostTensor::zeros(&[3 * h]);
        let wo = HostTensor::randn(&[h, h], 0.4, &mut rng);
        let y0 = attn_fwd(&x, &wqkv, &bqkv, &wo, nh);
        let mut x2 = x.clone();
        for d in 0..h {
            x2.data[2 * h + d] += 5.0; // perturb last position
        }
        let y1 = attn_fwd(&x2, &wqkv, &bqkv, &wo, nh);
        for d in 0..2 * h {
            assert!((y0.data[d] - y1.data[d]).abs() < 1e-6, "leak at {d}");
        }
    }

    #[test]
    fn head_shard_sum_equals_full_attention() {
        // Paper Eq. 4: sum over head shards of partials == full attention.
        use crate::model::partition;
        let mut rng = Rng::new(19);
        let (b, s, h, nh, n) = (2, 4, 8, 4, 2);
        let hd = h / nh;
        let x = HostTensor::randn(&[b, s, h], 0.8, &mut rng);
        let wqkv = HostTensor::randn(&[h, 3 * h], 0.3, &mut rng);
        let bqkv = HostTensor::randn(&[3 * h], 0.1, &mut rng);
        let wo = HostTensor::randn(&[h, h], 0.3, &mut rng);
        let full = attn_fwd(&x, &wqkv, &bqkv, &wo, nh);
        let mut acc = HostTensor::zeros(&[b, s, h]);
        for sh in 0..n {
            let shard = partition::attn_shard(&wqkv, &bqkv, &wo, sh, n, nh, hd);
            acc.add_assign(&attn_fwd(&x, &shard.wqkv, &shard.bqkv, &shard.wo, nh / n));
        }
        assert!(acc.allclose(&full, 1e-4), "diff {}", acc.max_abs_diff(&full));
    }

    #[test]
    fn mlp_shard_sum_equals_full() {
        use crate::model::partition;
        let mut rng = Rng::new(20);
        let (b, s, h, f, n) = (1, 3, 6, 12, 3);
        let x = HostTensor::randn(&[b, s, h], 0.8, &mut rng);
        let w1 = HostTensor::randn(&[h, f], 0.3, &mut rng);
        let b1 = HostTensor::randn(&[f], 0.1, &mut rng);
        let w2 = HostTensor::randn(&[f, h], 0.3, &mut rng);
        let full = mlp_fwd(&x, &w1, &b1, &w2);
        let mut acc = HostTensor::zeros(&[b, s, h]);
        for sh in 0..n {
            let shard = partition::mlp_shard(&w1, &b1, &w2, sh, n);
            acc.add_assign(&mlp_fwd(&x, &shard.w1, &shard.b1, &shard.w2));
        }
        assert!(acc.allclose(&full, 1e-4), "diff {}", acc.max_abs_diff(&full));
    }

    #[test]
    fn lmhead_shard_concat_equals_full() {
        use crate::model::partition;
        let mut rng = Rng::new(21);
        let (b, s, h, v, n) = (1, 3, 6, 8, 4);
        let x = HostTensor::randn(&[b, s, h], 0.8, &mut rng);
        let w = HostTensor::randn(&[h, v], 0.3, &mut rng);
        let full = lmhead_fwd(&x, &w);
        let parts: Vec<HostTensor> = (0..n)
            .map(|sh| lmhead_fwd(&x, &partition::shard_cols(&w, sh, n)))
            .collect();
        let cat = partition::unshard_cols(&parts);
        assert!(cat.allclose(&full, 1e-5));
    }

    // -- decode-kernel bitwise parity ---------------------------------------

    #[test]
    fn mm_into_matches_mm_bitwise() {
        let mut rng = Rng::new(30);
        let (m, k, n) = (3, 5, 4);
        let mut a = HostTensor::randn(&[m, k], 1.0, &mut rng);
        a.data[2] = 0.0; // exercise the skip-zero branch
        let b = HostTensor::randn(&[k, n], 1.0, &mut rng);
        let full = mm(&a.data, m, k, &b.data, n);
        let mut c = Vec::new();
        mm_into(&a.data, m, k, &b.data, n, &mut c);
        assert_eq!(full, c);
    }

    #[test]
    fn ln_rows_into_matches_ln_fwd_bitwise() {
        let mut rng = Rng::new(31);
        let h = 6;
        let x = HostTensor::randn(&[2, 3, h], 0.9, &mut rng);
        let g = HostTensor::randn(&[h], 0.2, &mut rng);
        let b = HostTensor::randn(&[h], 0.2, &mut rng);
        let full = ln_fwd(&x, &g, &b);
        let mut out = Vec::new();
        ln_rows_into(&x.data, &g, &b, &mut out);
        assert_eq!(full.data, out);
    }

    /// Every row of a cached incremental attention pass is bit-identical
    /// to the same row of `head_attention` — the decode/full parity the
    /// serving path rests on.
    #[test]
    fn decode_attention_matches_head_attention_bitwise() {
        let mut rng = Rng::new(32);
        let (s, hd) = (7, 4);
        let q = HostTensor::randn(&[s, hd], 0.7, &mut rng);
        let k = HostTensor::randn(&[s, hd], 0.7, &mut rng);
        let v = HostTensor::randn(&[s, hd], 0.7, &mut rng);
        let (_, full_o) = head_attention(&q.data, &k.data, &v.data, s, hd);
        let scale = 1.0 / (hd as f32).sqrt();
        // replay incrementally, splitting the cache into 3-row "pages"
        let pt = 3;
        let mut scores = vec![0.0f32; s];
        for i in 0..s {
            let len = i + 1;
            let qi = &q.data[i * hd..(i + 1) * hd];
            let mut max = f32::MIN;
            for pg in 0..len.div_ceil(pt) {
                let rows = pt.min(len - pg * pt);
                let krows = &k.data[pg * pt * hd..];
                max = attn_decode_scores(
                    qi, krows, rows, hd, 0, scale, max,
                    &mut scores[pg * pt..pg * pt + rows],
                );
            }
            softmax_decode(&mut scores[..len], max);
            let mut o = vec![0.0f32; hd];
            for pg in 0..len.div_ceil(pt) {
                let rows = pt.min(len - pg * pt);
                let vrows = &v.data[pg * pt * hd..];
                attn_decode_weighted_sum(
                    &scores[pg * pt..pg * pt + rows], vrows, hd, 0, &mut o,
                );
            }
            assert_eq!(&full_o[i * hd..(i + 1) * hd], &o[..], "row {i}");
        }
    }
}
