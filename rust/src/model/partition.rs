//! Partition strategies (paper §3.2): slicing full weights into the N
//! rank-shards each engine distributes, plus the exact inverses (unshard)
//! used to reassemble rotated gradients and to verify round-trips.
//!
//! - **Output-Partition** (Embedding, LM head): column shard of the output
//!   feature dimension; merge = concat.
//! - **Number-of-head-Partition** (Attention): Wqkv column-sharded by
//!   contiguous head groups (canonical column order `[3][NH][HD]`), Wo
//!   row-sharded; merge = add.
//! - **Input+Output pair** (MLP): w1 column shard + w2 row shard;
//!   merge = add.
//! - **Expert-Partition** (MoE): contiguous expert groups per shard.

use crate::tensor::HostTensor;

/// Columns `[start, start+len)` of the output dim — Output-Partition.
pub fn shard_cols(t: &HostTensor, s: usize, n: usize) -> HostTensor {
    let c = t.last_dim();
    assert_eq!(c % n, 0, "output dim {c} not divisible by {n}");
    t.slice_last(s * (c / n), c / n)
}

/// Inverse of [`shard_cols`]: concat shards back along the output dim.
pub fn unshard_cols(shards: &[HostTensor]) -> HostTensor {
    let refs: Vec<&HostTensor> = shards.iter().collect();
    HostTensor::concat_last(&refs)
}

/// Rows `[start, start+len)` of the input dim — the row-parallel half of a
/// Megatron pair (wo, w2).
pub fn shard_rows(t: &HostTensor, s: usize, n: usize) -> HostTensor {
    let r = t.shape[0];
    assert_eq!(r % n, 0, "input dim {r} not divisible by {n}");
    t.slice_first(s * (r / n), r / n)
}

pub fn unshard_rows(shards: &[HostTensor]) -> HostTensor {
    let mut shape = shards[0].shape.clone();
    shape[0] = shards.iter().map(|t| t.shape[0]).sum();
    let mut full = HostTensor::zeros(&shape);
    let mut off = 0;
    for sh in shards {
        full.write_slice_first(off, sh);
        off += sh.shape[0];
    }
    full
}

/// Head-partition shard of wqkv [H, 3H] (columns ordered `[3][NH][HD]`):
/// shard `s` takes heads `[s·NH/n, (s+1)·NH/n)` of each of q, k, v →
/// [H, 3·H/n]. The same column map shards bqkv [3H] → [3·H/n].
pub fn shard_qkv_cols(t: &HostTensor, s: usize, n: usize, heads: usize, head_dim: usize)
    -> HostTensor
{
    let h3 = t.last_dim();
    assert_eq!(h3, 3 * heads * head_dim, "wqkv/bqkv column count mismatch");
    assert_eq!(heads % n, 0, "heads {heads} not divisible by {n}");
    let nh_p = heads / n;
    let cols = qkv_shard_cols(s, n, heads, head_dim);
    let rows = t.rows();
    let mut shape = t.shape.clone();
    *shape.last_mut().unwrap() = 3 * nh_p * head_dim;
    let mut out = HostTensor::zeros(&shape);
    let oc = out.last_dim();
    for r in 0..rows {
        for (j, &c) in cols.iter().enumerate() {
            out.data[r * oc + j] = t.data[r * h3 + c];
        }
    }
    out
}

/// The column indices of head-shard `s` inside the canonical [3][NH][HD]
/// column order.
fn qkv_shard_cols(s: usize, n: usize, heads: usize, head_dim: usize) -> Vec<usize> {
    let nh_p = heads / n;
    let mut cols = Vec::with_capacity(3 * nh_p * head_dim);
    for q3 in 0..3 {
        for head in s * nh_p..(s + 1) * nh_p {
            for d in 0..head_dim {
                cols.push(q3 * heads * head_dim + head * head_dim + d);
            }
        }
    }
    cols
}

/// Inverse of [`shard_qkv_cols`].
pub fn unshard_qkv_cols(shards: &[HostTensor], heads: usize, head_dim: usize) -> HostTensor {
    let n = shards.len();
    let rows = shards[0].rows();
    let h3 = 3 * heads * head_dim;
    let mut shape = shards[0].shape.clone();
    *shape.last_mut().unwrap() = h3;
    let mut full = HostTensor::zeros(&shape);
    for (s, sh) in shards.iter().enumerate() {
        let cols = qkv_shard_cols(s, n, heads, head_dim);
        let sc = sh.last_dim();
        for r in 0..rows {
            for (j, &c) in cols.iter().enumerate() {
                full.data[r * h3 + c] = sh.data[r * sc + j];
            }
        }
    }
    full
}

/// The expert indices owned by shard `s` (contiguous groups).
pub fn expert_range(s: usize, n: usize, experts: usize) -> std::ops::Range<usize> {
    assert_eq!(experts % n, 0, "experts {experts} not divisible by {n}");
    let per = experts / n;
    s * per..(s + 1) * per
}

/// One unit's shard set, as the RTP/TP engines hold it.
#[derive(Debug, Clone, PartialEq)]
pub struct AttnShard {
    pub wqkv: HostTensor,
    pub bqkv: HostTensor,
    pub wo: HostTensor,
}

#[derive(Debug, Clone, PartialEq)]
pub struct MlpShard {
    pub w1: HostTensor,
    pub b1: HostTensor,
    pub w2: HostTensor,
}

pub fn attn_shard(
    wqkv: &HostTensor,
    bqkv: &HostTensor,
    wo: &HostTensor,
    s: usize,
    n: usize,
    heads: usize,
    head_dim: usize,
) -> AttnShard {
    AttnShard {
        wqkv: shard_qkv_cols(wqkv, s, n, heads, head_dim),
        bqkv: shard_qkv_cols(bqkv, s, n, heads, head_dim),
        wo: shard_rows(wo, s, n),
    }
}

pub fn mlp_shard(w1: &HostTensor, b1: &HostTensor, w2: &HostTensor, s: usize, n: usize)
    -> MlpShard
{
    MlpShard {
        w1: shard_cols(w1, s, n),
        b1: shard_cols(b1, s, n),
        w2: shard_rows(w2, s, n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn col_shard_roundtrip() {
        prop::check("cols roundtrip", 40, |rng| {
            let n = 1 + rng.below(4);
            let rows = 1 + rng.below(5);
            let cols = n * (1 + rng.below(4));
            let mut r = Rng::new(rng.next_u64());
            let t = HostTensor::randn(&[rows, cols], 1.0, &mut r);
            let shards: Vec<HostTensor> = (0..n).map(|s| shard_cols(&t, s, n)).collect();
            let back = unshard_cols(&shards);
            if back != t {
                return Err("cols roundtrip failed".into());
            }
            Ok(())
        });
    }

    #[test]
    fn row_shard_roundtrip() {
        prop::check("rows roundtrip", 40, |rng| {
            let n = 1 + rng.below(4);
            let rows = n * (1 + rng.below(4));
            let cols = 1 + rng.below(5);
            let mut r = Rng::new(rng.next_u64());
            let t = HostTensor::randn(&[rows, cols], 1.0, &mut r);
            let shards: Vec<HostTensor> = (0..n).map(|s| shard_rows(&t, s, n)).collect();
            let back = unshard_rows(&shards);
            if back != t {
                return Err("rows roundtrip failed".into());
            }
            Ok(())
        });
    }

    #[test]
    fn qkv_shard_roundtrip() {
        prop::check("qkv roundtrip", 30, |rng| {
            let heads = [2usize, 4, 8][rng.below(3)];
            let n = [1usize, 2][rng.below(2)].min(heads);
            let hd = 1 + rng.below(4);
            let h = heads * hd;
            let mut r = Rng::new(rng.next_u64());
            let t = HostTensor::randn(&[h, 3 * h], 1.0, &mut r);
            let shards: Vec<HostTensor> =
                (0..n).map(|s| shard_qkv_cols(&t, s, n, heads, hd)).collect();
            let back = unshard_qkv_cols(&shards, heads, hd);
            if back != t {
                return Err(format!("qkv roundtrip failed heads={heads} n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn qkv_shard_interleaves_q_k_v() {
        // heads=2, hd=1, h=2: full columns are [q0 q1 k0 k1 v0 v1];
        // shard 0 of n=2 must take [q0 k0 v0].
        let t = HostTensor::from_vec(&[1, 6], vec![10., 11., 20., 21., 30., 31.]);
        let s0 = shard_qkv_cols(&t, 0, 2, 2, 1);
        assert_eq!(s0.data, vec![10., 20., 30.]);
        let s1 = shard_qkv_cols(&t, 1, 2, 2, 1);
        assert_eq!(s1.data, vec![11., 21., 31.]);
    }

    #[test]
    fn bias_shards_like_weights() {
        // bqkv is 1-D [3H]; shard via the same column map (shape [3H] has
        // rows()==1).
        let b = HostTensor::from_vec(&[6], vec![10., 11., 20., 21., 30., 31.]);
        let s0 = shard_qkv_cols(&b, 0, 2, 2, 1);
        assert_eq!(s0.shape, vec![3]);
        assert_eq!(s0.data, vec![10., 20., 30.]);
    }

    #[test]
    fn expert_ranges_partition_evenly() {
        assert_eq!(expert_range(0, 2, 4), 0..2);
        assert_eq!(expert_range(1, 2, 4), 2..4);
        assert_eq!(expert_range(3, 4, 4), 3..4);
        // cover all experts exactly once
        let mut seen = vec![0; 8];
        for s in 0..4 {
            for e in expert_range(s, 4, 8) {
                seen[e] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn uneven_shard_rejected() {
        let t = HostTensor::zeros(&[2, 5]);
        shard_cols(&t, 0, 2);
    }
}
