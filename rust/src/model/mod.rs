//! The model layer: GPT-2/MoE parameter structures, the op catalog shared
//! with the AOT artifacts, the partition strategies of paper §3.2, and a
//! pure-rust oracle implementation of every op.
//!
//! The engines never hard-code shapes: everything flows from
//! [`ops::input_shapes`] / [`ops::output_shapes`], which mirror
//! `python/compile/aot.py::op_instances` exactly (cross-checked against the
//! manifest by `tests/integration_runtime.rs`).

pub mod oracle;
pub mod ops;
pub mod params;
pub mod partition;

pub use ops::{Op, OpCost};
pub use params::{ExpertParams, LayerParams, MlpParams, ModelParams};
