//! Full-model parameter (and gradient — same structure) containers.
//!
//! Canonical layouts match `python/compile/model.py`:
//!   wte [V, H], wpe [S, H]
//!   per layer: ln1_g/ln1_b [H], wqkv [H, 3H] (cols ordered [3][NH][HD]),
//!              bqkv [3H], wo [H, H] (rows = [NH][HD]), bo [H],
//!              ln2_g/ln2_b [H], then Dense {w1 [H,F], b1 [F], w2 [F,H],
//!              b2 [H]} or Moe {wr [H,E], experts: E × {w1 [H,Fe], b1 [Fe],
//!              w2 [Fe,H]}, b2 [H]}
//!   lnf_g/lnf_b [H], wlm [H, V] (untied LM head)
//!
//! The same struct doubles as the gradient container (`zeros_like`), and
//! `visit` / `zip_mut` provide the named traversal the optimizer and the
//! engine-equivalence tests are built on.

use crate::config::ModelCfg;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct ExpertParams {
    pub w1: HostTensor,
    pub b1: HostTensor,
    pub w2: HostTensor,
}

#[derive(Debug, Clone, PartialEq)]
pub enum MlpParams {
    Dense { w1: HostTensor, b1: HostTensor, w2: HostTensor, b2: HostTensor },
    Moe { wr: HostTensor, experts: Vec<ExpertParams>, b2: HostTensor },
}

#[derive(Debug, Clone, PartialEq)]
pub struct LayerParams {
    pub ln1_g: HostTensor,
    pub ln1_b: HostTensor,
    pub wqkv: HostTensor,
    pub bqkv: HostTensor,
    pub wo: HostTensor,
    pub bo: HostTensor,
    pub ln2_g: HostTensor,
    pub ln2_b: HostTensor,
    pub mlp: MlpParams,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ModelParams {
    pub wte: HostTensor,
    pub wpe: HostTensor,
    pub layers: Vec<LayerParams>,
    pub lnf_g: HostTensor,
    pub lnf_b: HostTensor,
    pub wlm: HostTensor,
}

/// GPT-2 style init: N(0, 0.02) weights, ones for LN gains, zero biases.
const INIT_STD: f32 = 0.02;

fn ones(shape: &[usize]) -> HostTensor {
    let mut t = HostTensor::zeros(shape);
    t.data.fill(1.0);
    t
}

impl ModelParams {
    pub fn init(cfg: &ModelCfg, rng: &mut Rng) -> Self {
        let (v, h, s, f) = (cfg.vocab, cfg.hidden, cfg.seq, cfg.ffn);
        let mk = |shape: &[usize], rng: &mut Rng| HostTensor::randn(shape, INIT_STD, rng);
        let layers = (0..cfg.layers)
            .map(|_| LayerParams {
                ln1_g: ones(&[h]),
                ln1_b: HostTensor::zeros(&[h]),
                wqkv: mk(&[h, 3 * h], rng),
                bqkv: HostTensor::zeros(&[3 * h]),
                wo: mk(&[h, h], rng),
                bo: HostTensor::zeros(&[h]),
                ln2_g: ones(&[h]),
                ln2_b: HostTensor::zeros(&[h]),
                mlp: if cfg.is_moe() {
                    MlpParams::Moe {
                        wr: mk(&[h, cfg.experts], rng),
                        experts: (0..cfg.experts)
                            .map(|_| ExpertParams {
                                w1: mk(&[h, cfg.expert_ffn], rng),
                                b1: HostTensor::zeros(&[cfg.expert_ffn]),
                                w2: mk(&[cfg.expert_ffn, h], rng),
                            })
                            .collect(),
                        b2: HostTensor::zeros(&[h]),
                    }
                } else {
                    MlpParams::Dense {
                        w1: mk(&[h, f], rng),
                        b1: HostTensor::zeros(&[f]),
                        w2: mk(&[f, h], rng),
                        b2: HostTensor::zeros(&[h]),
                    }
                },
            })
            .collect();
        ModelParams {
            wte: mk(&[v, h], rng),
            wpe: mk(&[s, h], rng),
            layers,
            lnf_g: ones(&[h]),
            lnf_b: HostTensor::zeros(&[h]),
            wlm: mk(&[h, v], rng),
        }
    }

    /// Same structure, all zeros — the gradient container.
    pub fn zeros_like(cfg: &ModelCfg) -> Self {
        let mut rng = Rng::new(0);
        let mut p = Self::init(cfg, &mut rng);
        p.visit_mut(&mut |_, t| t.data.fill(0.0));
        p
    }

    /// Visit every parameter with its canonical dotted name
    /// (`layers.3.wqkv`, `layers.0.mlp.experts.2.w1`, ...).
    pub fn visit(&self, f: &mut dyn FnMut(&str, &HostTensor)) {
        f("wte", &self.wte);
        f("wpe", &self.wpe);
        for (l, lp) in self.layers.iter().enumerate() {
            let pre = format!("layers.{l}");
            f(&format!("{pre}.ln1_g"), &lp.ln1_g);
            f(&format!("{pre}.ln1_b"), &lp.ln1_b);
            f(&format!("{pre}.wqkv"), &lp.wqkv);
            f(&format!("{pre}.bqkv"), &lp.bqkv);
            f(&format!("{pre}.wo"), &lp.wo);
            f(&format!("{pre}.bo"), &lp.bo);
            f(&format!("{pre}.ln2_g"), &lp.ln2_g);
            f(&format!("{pre}.ln2_b"), &lp.ln2_b);
            match &lp.mlp {
                MlpParams::Dense { w1, b1, w2, b2 } => {
                    f(&format!("{pre}.mlp.w1"), w1);
                    f(&format!("{pre}.mlp.b1"), b1);
                    f(&format!("{pre}.mlp.w2"), w2);
                    f(&format!("{pre}.mlp.b2"), b2);
                }
                MlpParams::Moe { wr, experts, b2 } => {
                    f(&format!("{pre}.mlp.wr"), wr);
                    for (e, ex) in experts.iter().enumerate() {
                        f(&format!("{pre}.mlp.experts.{e}.w1"), &ex.w1);
                        f(&format!("{pre}.mlp.experts.{e}.b1"), &ex.b1);
                        f(&format!("{pre}.mlp.experts.{e}.w2"), &ex.w2);
                    }
                    f(&format!("{pre}.mlp.b2"), b2);
                }
            }
        }
        f("lnf_g", &self.lnf_g);
        f("lnf_b", &self.lnf_b);
        f("wlm", &self.wlm);
    }

    pub fn visit_mut(&mut self, f: &mut dyn FnMut(&str, &mut HostTensor)) {
        f("wte", &mut self.wte);
        f("wpe", &mut self.wpe);
        for (l, lp) in self.layers.iter_mut().enumerate() {
            let pre = format!("layers.{l}");
            f(&format!("{pre}.ln1_g"), &mut lp.ln1_g);
            f(&format!("{pre}.ln1_b"), &mut lp.ln1_b);
            f(&format!("{pre}.wqkv"), &mut lp.wqkv);
            f(&format!("{pre}.bqkv"), &mut lp.bqkv);
            f(&format!("{pre}.wo"), &mut lp.wo);
            f(&format!("{pre}.bo"), &mut lp.bo);
            f(&format!("{pre}.ln2_g"), &mut lp.ln2_g);
            f(&format!("{pre}.ln2_b"), &mut lp.ln2_b);
            match &mut lp.mlp {
                MlpParams::Dense { w1, b1, w2, b2 } => {
                    f(&format!("{pre}.mlp.w1"), w1);
                    f(&format!("{pre}.mlp.b1"), b1);
                    f(&format!("{pre}.mlp.w2"), w2);
                    f(&format!("{pre}.mlp.b2"), b2);
                }
                MlpParams::Moe { wr, experts, b2 } => {
                    f(&format!("{pre}.mlp.wr"), wr);
                    for (e, ex) in experts.iter_mut().enumerate() {
                        f(&format!("{pre}.mlp.experts.{e}.w1"), &mut ex.w1);
                        f(&format!("{pre}.mlp.experts.{e}.b1"), &mut ex.b1);
                        f(&format!("{pre}.mlp.experts.{e}.w2"), &mut ex.w2);
                    }
                    f(&format!("{pre}.mlp.b2"), b2);
                }
            }
        }
        f("lnf_g", &mut self.lnf_g);
        f("lnf_b", &mut self.lnf_b);
        f("wlm", &mut self.wlm);
    }

    /// Pairwise traversal of two structurally-identical param sets
    /// (`self[k] op other[k]` for every parameter) — the optimizer update
    /// and the gradient-accumulation path.
    pub fn zip_mut(
        &mut self,
        other: &ModelParams,
        f: &mut dyn FnMut(&str, &mut HostTensor, &HostTensor),
    ) {
        let mut names = Vec::new();
        let mut tensors: Vec<*const HostTensor> = Vec::new();
        other.visit(&mut |n, t| {
            names.push(n.to_string());
            tensors.push(t as *const _);
        });
        let mut i = 0;
        self.visit_mut(&mut |n, t| {
            assert_eq!(n, names[i], "zip_mut structure mismatch");
            // SAFETY: `other` is borrowed immutably for the whole call and
            // visit order is deterministic; the raw pointer only bridges
            // the two closure passes.
            let o = unsafe { &*tensors[i] };
            f(n, t, o);
            i += 1;
        });
        assert_eq!(i, names.len(), "zip_mut arity mismatch");
    }

    /// `self += alpha * other` over every parameter.
    pub fn axpy(&mut self, alpha: f32, other: &ModelParams) {
        self.zip_mut(other, &mut |_, t, o| t.axpy(alpha, o));
    }

    pub fn scale(&mut self, alpha: f32) {
        self.visit_mut(&mut |_, t| t.scale(alpha));
    }

    pub fn num_params(&self) -> usize {
        let mut n = 0;
        self.visit(&mut |_, t| n += t.numel());
        n
    }

    pub fn bytes(&self) -> u64 {
        (self.num_params() * 4) as u64
    }

    /// Largest |self[k] - other[k]| over all parameters — the engine
    /// gradient-equivalence metric.
    pub fn max_abs_diff(&self, other: &ModelParams) -> f32 {
        let mut worst = 0.0f32;
        let mut tensors: Vec<*const HostTensor> = Vec::new();
        other.visit(&mut |_, t| tensors.push(t as *const _));
        let mut i = 0;
        self.visit(&mut |_, t| {
            let o = unsafe { &*tensors[i] };
            worst = worst.max(t.max_abs_diff(o));
            i += 1;
        });
        worst
    }

    /// Relative allclose over all parameters, reporting the first offender.
    pub fn allclose(&self, other: &ModelParams, tol: f32) -> Result<(), String> {
        let mut tensors: Vec<*const HostTensor> = Vec::new();
        other.visit(&mut |_, t| tensors.push(t as *const _));
        let mut i = 0;
        let mut bad: Option<String> = None;
        self.visit(&mut |n, t| {
            let o = unsafe { &*tensors[i] };
            if bad.is_none() && !t.allclose(o, tol) {
                bad = Some(format!("{n}: max diff {}", t.max_abs_diff(o)));
            }
            i += 1;
        });
        match bad {
            None => Ok(()),
            Some(msg) => Err(msg),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn tiny() -> ModelCfg {
        presets::get("tiny").unwrap()
    }

    #[test]
    fn param_count_matches_cfg_formula() {
        let cfg = tiny();
        let mut rng = Rng::new(1);
        let p = ModelParams::init(&cfg, &mut rng);
        assert_eq!(p.num_params(), cfg.params_total());
    }

    #[test]
    fn moe_param_count_matches() {
        let cfg = presets::get("tiny-moe").unwrap();
        let mut rng = Rng::new(1);
        let p = ModelParams::init(&cfg, &mut rng);
        assert_eq!(p.num_params(), cfg.params_total());
    }

    #[test]
    fn visit_and_visit_mut_agree() {
        let cfg = tiny();
        let mut rng = Rng::new(2);
        let mut p = ModelParams::init(&cfg, &mut rng);
        let mut names_a = Vec::new();
        p.visit(&mut |n, _| names_a.push(n.to_string()));
        let mut names_b = Vec::new();
        p.visit_mut(&mut |n, _| names_b.push(n.to_string()));
        assert_eq!(names_a, names_b);
        assert!(names_a.contains(&"layers.1.wqkv".to_string()));
    }

    #[test]
    fn zeros_like_is_zero_and_same_shape() {
        let cfg = tiny();
        let z = ModelParams::zeros_like(&cfg);
        z.visit(&mut |n, t| {
            assert!(t.data.iter().all(|&v| v == 0.0), "{n} not zero");
        });
        assert_eq!(z.num_params(), cfg.params_total());
    }

    #[test]
    fn axpy_accumulates() {
        let cfg = tiny();
        let mut rng = Rng::new(3);
        let a = ModelParams::init(&cfg, &mut rng);
        let mut acc = ModelParams::zeros_like(&cfg);
        acc.axpy(2.0, &a);
        acc.axpy(-2.0, &a);
        assert_eq!(acc.max_abs_diff(&ModelParams::zeros_like(&cfg)), 0.0);
    }

    #[test]
    fn init_is_seed_deterministic() {
        let cfg = tiny();
        let a = ModelParams::init(&cfg, &mut Rng::new(7));
        let b = ModelParams::init(&cfg, &mut Rng::new(7));
        assert_eq!(a.max_abs_diff(&b), 0.0);
        let c = ModelParams::init(&cfg, &mut Rng::new(8));
        assert!(a.max_abs_diff(&c) > 0.0);
    }

    #[test]
    fn allclose_reports_offender() {
        let cfg = tiny();
        let a = ModelParams::init(&cfg, &mut Rng::new(7));
        let mut b = a.clone();
        assert!(a.allclose(&b, 0.0).is_ok());
        b.layers[0].wo.data[0] += 1.0;
        let err = a.allclose(&b, 1e-3).unwrap_err();
        assert!(err.contains("layers.0.wo"), "{err}");
    }
}
